//! FIG3 harness bench: the iterations-to-1e-6 table on the three
//! datasets, m in {2..64}, DANE (mu = 0 / 3 lambda) vs ADMM.
//!
//! `DANE_BENCH_SCALE` divides dataset sizes (default 8).

use dane::comm::ExecTopology;
use dane::config::EngineKind;
use std::path::Path;

fn main() {
    let scale: usize = std::env::var("DANE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let engine = EngineKind::from_env("DANE_BENCH_ENGINE").expect("DANE_BENCH_ENGINE");
    let topology =
        ExecTopology::from_env("DANE_BENCH_TOPOLOGY").expect("DANE_BENCH_TOPOLOGY");
    println!("== fig3 bench (scale {scale}, engine {}) ==", engine.name());
    let t0 = std::time::Instant::now();
    let cols = dane::harness::fig3(scale, Path::new("results/fig3"), engine, topology)
        .expect("fig3 harness");
    // Shape checks mirroring the paper's table: DANE's row should be flat
    // in m until shards get small; report the spread.
    for c in &cols {
        for (label, vals) in &c.rows {
            let known: Vec<usize> = vals.iter().flatten().copied().collect();
            if known.is_empty() {
                continue;
            }
            let (mn, mx) = (
                known.iter().min().unwrap(),
                known.iter().max().unwrap(),
            );
            println!(
                "  [{}] {label}: min {mn} max {mx} (over m; * omitted)",
                c.dataset
            );
        }
    }
    println!("fig3 bench done in {:.1}s", t0.elapsed().as_secs_f64());
}
