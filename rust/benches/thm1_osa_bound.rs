//! THM1 bench: Monte-Carlo simulation of the Theorem-1 lower-bound
//! construction. OSA's MSE must plateau in m; the pooled ERM's must fall
//! ~1/m. Prints the table and asserts the ordering the theorem proves.

fn main() {
    println!("== thm1 bench ==");
    let t0 = std::time::Instant::now();
    let rows = dane::harness::thm1(400).expect("thm1 harness");
    let m1 = rows.iter().find(|r| r.m == 1).unwrap();
    let m64 = rows.iter().find(|r| r.m == 64).unwrap();
    let osa_gain = m1.mse_osa / m64.mse_osa;
    let erm_gain = m1.mse_erm / m64.mse_erm;
    println!(
        "m=1 -> m=64 MSE improvement: OSA {osa_gain:.1}x vs pooled ERM {erm_gain:.1}x"
    );
    assert!(
        erm_gain > 4.0 * osa_gain,
        "Theorem 1: ERM must outscale OSA in m ({erm_gain:.1}x vs {osa_gain:.1}x)"
    );
    println!("thm1 bench done in {:.1}s", t0.elapsed().as_secs_f64());
}
