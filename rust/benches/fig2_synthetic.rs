//! FIG2 harness bench: regenerates the paper's fig. 2 grid (DANE vs ADMM
//! over m x N on the synthetic ridge model) and prints the series the
//! figure plots (log10 suboptimality per iteration) plus per-cell rate
//! summaries.
//!
//! `DANE_BENCH_SCALE` divides the sample sizes (default 8 keeps `cargo
//! bench` under a few minutes on one core; scale 1 is the paper-size
//! harness recorded in EXPERIMENTS.md).

use dane::comm::ExecTopology;
use dane::config::EngineKind;
use std::path::Path;

fn main() {
    let scale: usize = std::env::var("DANE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let engine = EngineKind::from_env("DANE_BENCH_ENGINE").expect("DANE_BENCH_ENGINE");
    let topology =
        ExecTopology::from_env("DANE_BENCH_TOPOLOGY").expect("DANE_BENCH_TOPOLOGY");
    println!(
        "== fig2 bench (scale {scale}; DANE_BENCH_SCALE to change; engine {}; \
         DANE_BENCH_ENGINE=serial|threaded) ==",
        engine.name()
    );
    let t0 = std::time::Instant::now();
    let cells = dane::harness::fig2(scale, Path::new("results/fig2"), engine, topology)
        .expect("fig2 harness");
    println!("\nfig2 series (log10 suboptimality by iteration):");
    for c in &cells {
        let series: Vec<String> =
            c.log10_subopt.iter().take(10).map(|v| format!("{v:.1}")).collect();
        println!(
            "  {:>4} m={:<3} N={:<6} [{}]",
            c.algo,
            c.m,
            c.n_total,
            series.join(", ")
        );
    }
    println!("fig2 bench done in {:.1}s", t0.elapsed().as_secs_f64());
}
