//! Design-choice ablations (DESIGN.md §6/§8):
//!
//! 1. local-solve exactness: DANE with exact (cached Cholesky) vs inexact
//!    (Newton-CG at loosening tolerances) local solves — how precise must
//!    the inner solver be before the outer rate degrades?
//! 2. mu sweep: the paper's {0, lambda, 3 lambda} plus larger values,
//!    showing the DANE -> gradient-descent continuum of §3.
//! 3. eta sweep: step-size sensitivity around the paper's eta = 1.
//! 4. collective topology: the alpha-beta model's verdict on star vs ring
//!    vs tree for DANE's d-sized payloads across m.

use dane::comm::{NetModel, Topology};
use dane::coordinator::dane as dane_algo;
use dane::coordinator::{RunCtx, SerialCluster};
use dane::data::synthetic_fig2;
use dane::loss::{Objective, Ridge, SmoothHinge};
use dane::solver::erm_solve;
use dane::solver::newton_cg::NewtonCgOptions;
use std::sync::Arc;

fn main() {
    abl_local_solve_exactness();
    abl_mu_sweep();
    abl_eta_sweep();
    abl_topology();
}

/// 1. Inexact local solves: loosen the worker Newton-CG budget.
fn abl_local_solve_exactness() {
    println!("== ablation: local-solve exactness (hinge, m=8) ==");
    let lam = 1e-2;
    let ds = dane::data::covtype_like(8192, 64, 11);
    let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    println!("{:>12} {:>10} {:>14}", "grad_tol", "cg_iters", "iters to 1e-6");
    for (grad_tol, cg_iters) in
        [(1e-10, 500usize), (1e-6, 100), (1e-3, 20), (1e-1, 4)]
    {
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 8, 3);
        for w in cluster.workers_mut() {
            w.set_newton_options(NewtonCgOptions {
                grad_tol,
                cg_max_iters: cg_iters,
                max_newton: 20,
                ..Default::default()
            });
        }
        let ctx = RunCtx::new(60).with_reference(phi_star).with_tol(1e-6);
        let opts = dane_algo::DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() };
        let res = dane_algo::run(&mut cluster, &opts, &ctx).expect("run");
        println!(
            "{grad_tol:>12.0e} {cg_iters:>10} {:>14}",
            res.trace
                .rounds_to_tol(1e-6)
                .map(|r| r.to_string())
                .unwrap_or_else(|| "*".into())
        );
    }
}

/// 2. mu sweep (the DANE -> GD continuum of §3).
fn abl_mu_sweep() {
    println!("\n== ablation: mu sweep (ridge fig2, m=8, N=8192) ==");
    let lam = 0.01;
    let ds = synthetic_fig2(8192, 64, lam / 2.0, 5);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    println!("{:>12} {:>14} {:>18}", "mu/lambda", "iters to 1e-9", "mean contraction");
    for mu_mult in [0.0, 1.0, 3.0, 30.0, 300.0] {
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 8, 3);
        let ctx = RunCtx::new(300).with_reference(phi_star).with_tol(1e-9);
        let opts = dane_algo::DaneOptions { eta: 1.0, mu: mu_mult * lam, ..Default::default() };
        let res = dane_algo::run(&mut cluster, &opts, &ctx).expect("run");
        let f = res.trace.contraction_factors();
        let k = f.len().min(5).max(1);
        let rate = f.iter().take(k).sum::<f64>() / k as f64;
        println!(
            "{mu_mult:>12} {:>14} {rate:>18.4}",
            res.trace
                .rounds_to_tol(1e-9)
                .map(|r| r.to_string())
                .unwrap_or_else(|| "*".into())
        );
    }
}

/// 3. eta sweep.
fn abl_eta_sweep() {
    println!("\n== ablation: eta sweep (ridge fig2, m=8, N=8192, mu=0) ==");
    let lam = 0.01;
    let ds = synthetic_fig2(8192, 64, lam / 2.0, 5);
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
    println!("{:>8} {:>14}", "eta", "iters to 1e-9");
    for eta in [0.25, 0.5, 1.0, 1.5] {
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 8, 3);
        let ctx = RunCtx::new(400).with_reference(phi_star).with_tol(1e-9);
        let opts = dane_algo::DaneOptions { eta, mu: 0.0, ..Default::default() };
        let res = dane_algo::run(&mut cluster, &opts, &ctx).expect("run");
        println!(
            "{eta:>8} {:>14}",
            res.trace
                .rounds_to_tol(1e-9)
                .map(|r| r.to_string())
                .unwrap_or_else(|| "*".into())
        );
    }
}

/// 4. Topology cost model for DANE payloads.
fn abl_topology() {
    println!("\n== ablation: collective topology (alpha=50us, 10Gb/s, d=500 payload) ==");
    println!("{:>6} {:>12} {:>12} {:>12}", "m", "star (us)", "ring (us)", "tree (us)");
    let bytes = 500 * 8;
    for m in [4usize, 16, 64, 256] {
        let t = |topo| {
            NetModel::new(50e-6, 8.0 / 10e9, topo).collective_seconds(m, bytes) * 1e6
        };
        println!(
            "{m:>6} {:>12.1} {:>12.1} {:>12.1}",
            t(Topology::Star),
            t(Topology::Ring),
            t(Topology::Tree)
        );
    }
    println!("(latency-bound at these payloads: tree wins — the sequential star serializes at the root; ring only pays off for MB+ payloads)");
}
