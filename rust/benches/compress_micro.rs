//! Compression micro-benchmarks + the bytes-vs-loss table
//! (EXPERIMENTS.md §Compression).
//!
//! Two layers:
//!
//! 1. **codec throughput** — encode/decode of each [`CodedVec`] codec
//!    (f32 downcast, top-k sparsification, stochastic quantization) at
//!    d = 4096, plus the leader-side `grad_cmd` path with its
//!    error-feedback accumulator and the full `CompressedVec` frame
//!    encode;
//! 2. **bytes vs loss, end-to-end** — a DANE run on a real socket
//!    cluster (in-process `worker::serve` sessions over loopback TCP,
//!    same frames as worker processes) under each codec, recording the
//!    final objective, the measured `wire_bytes`, and the
//!    `payload_bytes_raw` counterfactual. This is the tentpole claim in
//!    numbers: top-k (k = d/10) with error feedback matches the
//!    uncompressed objective to < 1e-3 relative while moving >= 5x
//!    fewer round bytes.
//!
//! The run is serialized to `BENCH_compress.json` at the repo root:
//! the `dane-bench-v1` timing schema plus a `bytes_vs_loss` section.
//! `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` shrink the run for CI's
//! bench-smoke job; `BENCH_LABEL` overrides the git label.

use dane::comm::compress::{Codec, CodedVec, LeaderCompressor};
use dane::comm::wire::{self, Command};
use dane::comm::{ExecTopology, NetModel};
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::Cluster;
use dane::data::{synthetic_fig2, Dataset};
use dane::util::bench::{black_box, git_label, Bencher};
use dane::util::{Json, Rng64};
use dane::worker::serve;
use std::net::TcpListener;
use std::sync::Arc;

/// Repo root (one above the cargo manifest), where the trajectory lands.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_compress.json");

/// See `wire_micro::spawn_inprocess_workers`: loopback serve sessions
/// indistinguishable from worker processes at the frame level.
fn spawn_inprocess_workers(m: usize) -> Vec<String> {
    let mut addrs = Vec::with_capacity(m);
    for _ in 0..m {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        std::thread::spawn(move || {
            let _ = serve::serve_listener(listener);
        });
    }
    addrs
}

/// One end-to-end DANE run under `codec`; returns
/// (final objective, round wire_bytes, payload_bytes_raw).
fn bytes_vs_loss_run(
    ds: &Dataset,
    m: usize,
    rounds: usize,
    codec: Option<Codec>,
) -> (f64, u64, u64) {
    let addrs = spawn_inprocess_workers(m);
    let mut c = TcpCluster::connect(
        ds,
        LossKind::Ridge,
        0.01,
        &addrs,
        7,
        NetModel::free(),
        None,
        None,
        ExecTopology::Star,
    )
    .expect("tcp cluster over in-process workers");
    if let Some(codec) = codec {
        c.set_compression(codec, true, 11);
    }
    let d = ds.d();
    let mut w = vec![0.0; d];
    for _ in 0..rounds {
        let (g, _) = c.grad_and_loss(&w).expect("grad round");
        w = c.dane_round(&w, &g, 1.0, 0.0).expect("solve round");
    }
    // Snapshot the round traffic BEFORE the (uncompressed)
    // instrumentation eval, so the ratio is codec round bytes only.
    let stats = c.comm_stats();
    let (_, objective) = c.eval_grad_loss(&w).expect("final eval");
    (objective, stats.wire_bytes, stats.payload_bytes_raw)
}

fn main() {
    let b = Bencher::from_env(700, 120, 40);
    println!("== compress_micro (codecs d=4096; bytes-vs-loss m=4) ==");

    // ---- codec throughput -------------------------------------------
    let d = 4096usize;
    let k = d / 10;
    let mut rng = Rng64::seed_from_u64(3);
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut dec = Vec::new();

    let cases = [
        ("f32", Codec::F32),
        ("topk k=d/10", Codec::TopK { k }),
        ("quant b=4", Codec::Quant { bits: 4 }),
    ];
    for (name, codec) in cases {
        let mut enc_rng = Rng64::seed_from_u64(5);
        b.bench(&format!("encode {name} d=4096"), || {
            black_box(CodedVec::encode(codec, &x, &mut enc_rng));
        });
        let coded = CodedVec::encode(codec, &x, &mut Rng64::seed_from_u64(5));
        b.bench(&format!("decode {name} d=4096"), || {
            coded.decode_into(&mut dec);
            black_box(&dec);
        });
    }

    // leader path: compress + error-feedback accumulate in one call
    let mut comp = LeaderCompressor::new(Codec::TopK { k }, true, 11);
    b.bench("leader grad_cmd topk+ef d=4096", || {
        black_box(comp.grad_cmd(&x));
    });

    // the full typed frame, as the engines put it on the socket
    let payload = Arc::new(comp.grad_cmd(&x));
    let mut buf = Vec::new();
    b.bench("encode CompressedVec frame topk d=4096", || {
        wire::encode_command(&Command::CompressedVec(payload.clone()), &mut buf)
            .expect("encode frame");
        black_box(&buf);
    });

    // ---- bytes vs loss, end-to-end ----------------------------------
    let (m, dd, rounds) = (4usize, 512usize, 20usize);
    let ds = synthetic_fig2(4096, dd, 0.005, 42);
    let runs = [
        ("none", None),
        ("f32", Some(Codec::F32)),
        ("topk k=d/10", Some(Codec::TopK { k: dd / 10 })),
        ("quant b=4", Some(Codec::Quant { bits: 4 })),
    ];
    let mut table = Vec::new();
    for (name, codec) in runs {
        let (objective, wire, raw) = bytes_vs_loss_run(&ds, m, rounds, codec);
        println!(
            "codec {name:<12} objective {objective:.9e}  wire {wire:>9}  raw {raw:>9}  \
             ratio {:.2}x",
            raw as f64 / wire.max(1) as f64
        );
        table.push((name, objective, wire, raw));
    }
    let (base_obj, base_wire) = (table[0].1, table[0].2);
    assert_eq!(
        table[0].2, table[0].3,
        "codec none must report payload_bytes_raw == wire_bytes"
    );
    let topk = &table[2];
    let rel = (topk.1 - base_obj).abs() / base_obj.abs().max(f64::MIN_POSITIVE);
    let ratio = base_wire as f64 / topk.2.max(1) as f64;
    println!("top-k vs none: relative objective gap {rel:.3e}, byte ratio {ratio:.2}x");
    assert!(
        rel < 1e-3,
        "top-k+EF final objective {:.9e} drifted {rel:.3e} from uncompressed {base_obj:.9e}",
        topk.1
    );
    assert!(
        ratio >= 5.0,
        "top-k round bytes {} vs uncompressed {base_wire}: only {ratio:.2}x",
        topk.2
    );

    // ---- JSON trajectory (timings + the bytes-vs-loss table) --------
    let results: Vec<Json> = b
        .records()
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::str(r.name.clone())),
                ("median_ns", Json::num(r.median_ns)),
                ("p25_ns", Json::num(r.p25_ns)),
                ("p75_ns", Json::num(r.p75_ns)),
                ("iters_per_sample", Json::num(r.iters_per_sample as f64)),
                ("samples", Json::num(r.samples as f64)),
            ])
        })
        .collect();
    let bvl: Vec<Json> = table
        .iter()
        .map(|(name, objective, wire, raw)| {
            Json::obj(vec![
                ("codec", Json::str(*name)),
                ("final_objective", Json::num(*objective)),
                ("wire_bytes", Json::num(*wire as f64)),
                ("payload_bytes_raw", Json::num(*raw as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("schema", Json::str("dane-bench-v1")),
        ("bench", Json::str("compress_micro")),
        ("label", Json::str(git_label())),
        ("results", Json::Arr(results)),
        ("bytes_vs_loss", Json::Arr(bvl)),
    ]);
    std::fs::write(BENCH_JSON, doc.to_string_pretty() + "\n")
        .expect("write BENCH_compress.json");
    println!("wrote {BENCH_JSON}");
}
