//! FIG4 harness bench: test-loss-vs-iteration curves at m = 64 for
//! DANE(mu = 3 lambda), ADMM and bias-corrected OSA, with the exact
//! minimizer's test loss as the "Opt" line.
//!
//! `DANE_BENCH_SCALE` divides dataset sizes (default 8).

use dane::comm::ExecTopology;
use dane::config::EngineKind;
use std::path::Path;

fn main() {
    let scale: usize = std::env::var("DANE_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let engine = EngineKind::from_env("DANE_BENCH_ENGINE").expect("DANE_BENCH_ENGINE");
    let topology =
        ExecTopology::from_env("DANE_BENCH_TOPOLOGY").expect("DANE_BENCH_TOPOLOGY");
    println!("== fig4 bench (scale {scale}, engine {}) ==", engine.name());
    let t0 = std::time::Instant::now();
    let panels = dane::harness::fig4(scale, Path::new("results/fig4"), engine, topology)
        .expect("fig4 harness");
    for p in &panels {
        println!("  [{}] opt test loss {:.6}", p.dataset, p.opt_test_loss);
        for (label, series) in &p.series {
            let tail = series.last().copied().unwrap_or(f64::NAN);
            println!(
                "    {label:>12}: final test loss {tail:.6} (gap to opt {:+.2e})",
                tail - p.opt_test_loss
            );
        }
    }
    println!("fig4 bench done in {:.1}s", t0.elapsed().as_secs_f64());
}
