//! Wire / topology micro-benchmarks (EXPERIMENTS.md §Topologies).
//!
//! Two layers, measured with the in-tree criterion-style harness:
//!
//! 1. **codec throughput** — encode/decode of the hot round frames
//!    (GradLoss command, DaneSolve command, VecScalar reply) at the
//!    canonical d = 512;
//! 2. **one-collective round-trip latency** — a full `grad_and_loss`
//!    (broadcast + gather + rank-order fold) on a real socket cluster,
//!    for the three execution strategies `star-seq` / `star` / `tree`
//!    at m in {4, 8, 16}. Workers are in-process threads serving the
//!    genuine `worker::serve` session over loopback TCP — the same
//!    frames, relays and bundles as worker processes, minus the process
//!    spawn noise, so the numbers isolate the *collective execution*
//!    cost the topology layer exists to cut.
//!
//! The run is serialized to `BENCH_wire.json` at the repo root (the
//! same `dane-bench-v1` schema as `BENCH_hotpath.json`), which is the
//! machine-readable perf trajectory topology claims are checked
//! against. `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` shrink the run for
//! CI's bench-smoke job; `BENCH_LABEL` overrides the git label.

use dane::comm::wire::{self, Command, Reply};
use dane::comm::{ExecTopology, NetModel};
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::Cluster;
use dane::data::{synthetic_fig2, Dataset};
use dane::util::bench::{black_box, git_label, Bencher};
use dane::util::Rng64;
use dane::worker::serve;
use std::net::TcpListener;
use std::sync::Arc;

/// Repo root (one above the cargo manifest), where the trajectory lands.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_wire.json");

/// Bind m loopback listeners, serve each on an in-process thread, and
/// return the addresses for `TcpCluster::connect`. The serve sessions
/// keep their listeners, so tree parents can be accepted exactly like
/// worker processes do.
fn spawn_inprocess_workers(m: usize) -> Vec<String> {
    let mut addrs = Vec::with_capacity(m);
    for _ in 0..m {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        std::thread::spawn(move || {
            // Clean exit on leader hangup; a bench must not panic the
            // process on teardown races.
            let _ = serve::serve_listener(listener);
        });
    }
    addrs
}

fn cluster(ds: &Dataset, m: usize, topology: ExecTopology) -> TcpCluster {
    let addrs = spawn_inprocess_workers(m);
    TcpCluster::connect(
        ds,
        LossKind::Ridge,
        0.01,
        &addrs,
        7,
        NetModel::free(),
        None,
        None,
        topology,
    )
    .expect("tcp cluster over in-process workers")
}

fn main() {
    let b = Bencher::from_env(700, 120, 40);
    println!("== wire_micro (codec d=512; collectives m in {{4,8,16}}) ==");

    // ---- codec throughput -------------------------------------------
    let d = 512usize;
    let mut rng = Rng64::seed_from_u64(3);
    let w: Arc<Vec<f64>> = Arc::new((0..d).map(|_| rng.normal()).collect());
    let g: Arc<Vec<f64>> = Arc::new((0..d).map(|_| rng.normal()).collect());
    let mut buf = Vec::new();

    let grad_cmd = Command::GradLoss { w: w.clone(), out: Vec::new() };
    b.bench("encode GradLoss d=512", || {
        wire::encode_command(&grad_cmd, &mut buf).unwrap();
        black_box(&buf);
    });
    wire::encode_command(&grad_cmd, &mut buf).unwrap();
    let grad_body = buf[4..].to_vec();
    b.bench("decode GradLoss d=512", || {
        black_box(wire::decode_command(&grad_body).unwrap());
    });

    let solve_cmd = Command::DaneSolve {
        w_prev: w.clone(),
        g: g.clone(),
        eta: 1.0,
        mu: 0.01,
        out: Vec::new(),
    };
    b.bench("encode DaneSolve d=512", || {
        wire::encode_command(&solve_cmd, &mut buf).unwrap();
        black_box(&buf);
    });

    let reply = Reply::VecScalar((0..d).map(|_| rng.normal()).collect(), 0.5);
    b.bench("encode VecScalar reply d=512", || {
        wire::encode_reply(&reply, &mut buf).unwrap();
        black_box(&buf);
    });
    wire::encode_reply(&reply, &mut buf).unwrap();
    let reply_body = buf[4..].to_vec();
    b.bench("decode VecScalar reply d=512", || {
        black_box(wire::decode_reply(&reply_body).unwrap());
    });

    // Bulk f64 decode throughput: d = 4096 is payload-dominated, so this
    // entry tracks the chunked `wire::take_f64s` fast path rather than
    // the per-frame fixed costs the d = 512 entries mix in.
    let big = Reply::VecScalar((0..4096).map(|_| rng.normal()).collect(), 0.5);
    wire::encode_reply(&big, &mut buf).unwrap();
    let big_body = buf[4..].to_vec();
    b.bench("decode VecScalar reply d=4096", || {
        black_box(wire::decode_reply(&big_body).unwrap());
    });

    // ---- one-collective round-trip latency --------------------------
    // Small shards keep the compute share negligible, so the number is
    // dominated by what we are measuring: frames on the wire and the
    // leader's fan-out/fan-in strategy.
    let strategies = [
        ExecTopology::StarSeq,
        ExecTopology::Star,
        ExecTopology::Tree,
    ];
    for m in [4usize, 8, 16] {
        let ds = synthetic_fig2(64 * m, 64, 0.005, 42);
        let probe = vec![0.05; 64];
        // reference result from the first strategy, to pin bit-parity
        // across strategies while we are at it
        let mut reference: Option<(Vec<f64>, f64)> = None;
        for topo in strategies {
            let mut c = cluster(&ds, m, topo);
            let (g0, l0) = c.grad_and_loss(&probe).expect("collective");
            match &reference {
                None => reference = Some((g0, l0)),
                Some((gr, lr)) => {
                    assert_eq!(gr, &g0, "m={m} {}: gradient drifted", topo.name());
                    assert_eq!(*lr, l0, "m={m} {}: loss drifted", topo.name());
                }
            }
            b.bench(&format!("grad_and_loss m={m} {}", topo.name()), || {
                black_box(c.grad_and_loss(&probe).expect("collective"));
            });
        }
    }

    // ---- strategy summary + JSON trajectory -------------------------
    for m in [4usize, 8, 16] {
        let seq = b.median_ns_of(&format!("grad_and_loss m={m} star-seq"));
        let star = b.median_ns_of(&format!("grad_and_loss m={m} star"));
        let tree = b.median_ns_of(&format!("grad_and_loss m={m} tree"));
        if let (Some(seq), Some(star), Some(tree)) = (seq, star, tree) {
            println!(
                "m={m:<3} star-seq/star {:.2}x   star-seq/tree {:.2}x",
                seq / star,
                seq / tree
            );
        }
    }
    b.write_json(std::path::Path::new(BENCH_JSON), "wire_micro", &git_label())
        .expect("write BENCH_wire.json");
    println!("wrote {BENCH_JSON}");
}
