//! Sparse-at-scale micro-benchmarks (EXPERIMENTS.md §Scale).
//!
//! Three layers of the sparse data plane, measured with the in-tree
//! criterion-style harness:
//!
//! 1. **CSR kernels** — serial `matvec` vs deterministic `par_matvec`
//!    at t in {2, 4, 8} on square sparse instances (the parallel kernel
//!    is bit-identical to the serial one by construction, asserted here
//!    before timing);
//! 2. **matrix-free local solve** — a full DANE Newton-CG local solve
//!    on a sparse shard across a (d, n) sweep, the O(nnz)-per-HVP path
//!    that replaces the d x d Gram/Cholesky at scale;
//! 3. **by-ref startup plane** — `LineIndex::build` plus one shard's
//!    `load_rows` on a generated LIBSVM file, the per-worker disk cost
//!    that Init-by-reference trades against shipping O(n·d) shard bytes
//!    (the corresponding frame sizes are printed next to the timings).
//!
//! The run is serialized to `BENCH_scale.json` at the repo root (the
//! same `dane-bench-v1` schema as the other BENCH_*.json trajectories).
//! `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` shrink the run for CI's
//! bench-smoke job; `BENCH_LABEL` overrides the git label.

use dane::comm::wire::{self, Command, InitPayload, InitRefPayload};
use dane::data::{shard_indices, sparse_ridge, Shard};
use dane::linalg::DataMatrix;
use dane::loss::{Objective, Ridge};
use dane::util::bench::{black_box, fmt_ns, git_label, Bencher};
use dane::worker::Worker;
use std::sync::Arc;

/// Repo root (one above the cargo manifest), where the trajectory lands.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_scale.json");

const NNZ_PER_ROW: usize = 8;

fn main() {
    let b = Bencher::from_env(500, 100, 40);
    println!("== scale_micro (sparse data plane; nnz/row = {NNZ_PER_ROW}) ==");

    // ---- 1. CSR kernels: matvec vs par_matvec -----------------------
    for (n, d) in [(10_000usize, 10_000usize), (50_000, 50_000)] {
        let ds = sparse_ridge(n, d, NNZ_PER_ROW, 11);
        let DataMatrix::Sparse(x) = &ds.x else {
            panic!("sparse_ridge builds CSR");
        };
        let v: Vec<f64> = (0..d).map(|j| (j % 17) as f64 * 0.125 - 1.0).collect();
        let mut serial = vec![0.0; n];
        let mut par = vec![0.0; n];
        x.matvec(&v, &mut serial);
        b.bench(&format!("matvec n=d={n} serial"), || {
            x.matvec(&v, &mut par);
            black_box(&par);
        });
        for t in [2usize, 4, 8] {
            // parity first: the deterministic split must be bit-exact
            x.par_matvec(&v, &mut par, t);
            assert_eq!(serial, par, "par_matvec t={t} drifted from serial");
            b.bench(&format!("matvec n=d={n} par t={t}"), || {
                x.par_matvec(&v, &mut par, t);
                black_box(&par);
            });
        }
    }

    // ---- 2. matrix-free DANE local solve ----------------------------
    // One shard's worth of rows at each scale; the Newton-CG path is
    // what every sparse worker runs each round instead of a Cholesky.
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.1));
    for (n, d) in [(4_096usize, 10_000usize), (4_096, 50_000)] {
        let ds = sparse_ridge(n, d, NNZ_PER_ROW, 23);
        let shard = Shard::new(ds.x.clone(), ds.y.clone());
        let mut wk = Worker::new(0, shard, obj.clone());
        let w_prev = vec![0.0; d];
        let mut g = vec![0.0; d];
        wk.grad(&w_prev, &mut g).expect("gradient");
        let mut out = Vec::new();
        b.bench(&format!("dane_local_solve sparse n={n} d={d}"), || {
            wk.dane_local_solve_into(&w_prev, &g, 1.0, 0.0, &mut out)
                .expect("matrix-free local solve");
            black_box(&out);
        });
        assert!(
            !wk.quad_cache_built(),
            "sparse local solve must never build the dense Gram"
        );
    }

    // ---- 3. by-ref startup plane ------------------------------------
    let (n, d, m) = (20_000usize, 5_000usize, 4usize);
    let ds = sparse_ridge(n, d, NNZ_PER_ROW, 31);
    let dir = dane::util::tempdir::TempDir::new("scale-micro").expect("tempdir");
    let path = dir.path().join("scale.svm");
    {
        use std::io::Write;
        let file = std::fs::File::create(&path).expect("create libsvm file");
        let mut out = std::io::BufWriter::new(file);
        let DataMatrix::Sparse(x) = &ds.x else { panic!("sparse") };
        for i in 0..n {
            let label = if ds.y[i] >= 0.0 { "+1" } else { "-1" };
            write!(out, "{label}").unwrap();
            let (idx, val) = x.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                write!(out, " {}:{}", j + 1, v).unwrap();
            }
            writeln!(out).unwrap();
        }
    }
    b.bench(&format!("LineIndex::build n={n}"), || {
        black_box(dane::data::libsvm::LineIndex::build(&path).expect("index"));
    });
    let rows = shard_indices(n, m, 7);
    b.bench(&format!("load_rows shard n/m={}", rows[0].len()), || {
        black_box(
            dane::data::libsvm::load_rows(&path, d, &rows[0]).expect("shard load"),
        );
    });

    // frame sizes: what by-ref actually saves at startup
    let shards = dane::data::shard_dataset(&ds, m, 7);
    let mut buf = Vec::new();
    wire::encode_command(
        &Command::Init(Box::new(InitPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            shard: shards[0].clone(),
        })),
        &mut buf,
    )
    .expect("encode Init");
    let by_value = buf.len();
    wire::encode_command(
        &Command::InitRef(Box::new(InitRefPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            path: path.to_string_lossy().into_owned(),
            dim: d,
            n,
            machines: m,
            shard_seed: 7,
        })),
        &mut buf,
    )
    .expect("encode InitRef");
    let by_ref = buf.len();
    println!(
        "startup frame, one worker (n={n} d={d} m={m}): by-value {by_value} B, \
         by-ref {by_ref} B ({:.0}x smaller)",
        by_value as f64 / by_ref as f64
    );

    // ---- summary + JSON trajectory ----------------------------------
    for (n, _) in [(10_000usize, 0usize), (50_000, 0)] {
        if let (Some(serial), Some(par4)) = (
            b.median_ns_of(&format!("matvec n=d={n} serial")),
            b.median_ns_of(&format!("matvec n=d={n} par t=4")),
        ) {
            println!(
                "n=d={n:<6} serial {} vs par t=4 {} ({:.2}x)",
                fmt_ns(serial),
                fmt_ns(par4),
                serial / par4
            );
        }
    }
    b.write_json(std::path::Path::new(BENCH_JSON), "scale_micro", &git_label())
        .expect("write BENCH_scale.json");
    println!("wrote {BENCH_JSON}");
}
