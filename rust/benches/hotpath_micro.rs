//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf).
//!
//! Criterion-style timing (in-tree harness, util::bench) of every
//! operation on the DANE hot path, bottom-up: vector kernels, dense and
//! sparse matvecs, Gram assembly, Cholesky factor/solve, CG, the cached
//! quadratic local solve, a full DANE round, and the PJRT artifact calls.
//! The canonical shard is 2048 x 512 (matching the AOT artifact shape).

use dane::coordinator::{Cluster, RunCtx, SerialCluster};
use dane::data::{shard_dataset, synthetic_fig2};
use dane::linalg::cg::{cg_solve, CgScratch};
use dane::linalg::{ops, CholeskyFactor, DataMatrix};
use dane::loss::{Objective, Ridge, ShardHvp, SmoothHinge};
use dane::runtime::{ArtifactRegistry, PjrtSession};
use dane::solver::erm_solve;
use dane::util::bench::{black_box, Bencher};
use dane::util::Rng64;
use dane::worker::Worker;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    let b = Bencher {
        measure_time: Duration::from_millis(900),
        warmup_time: Duration::from_millis(150),
        max_samples: 40,
    };
    println!("== hotpath_micro (canonical shard 2048x512) ==");

    let (n, d) = (2048usize, 512usize);
    let ds = synthetic_fig2(n, d, 0.005, 42);
    let shard = ds.as_single_shard();
    let lam = 0.01;
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));

    // ---- L0 vector kernels ------------------------------------------
    let mut rng = Rng64::seed_from_u64(1);
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut y: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    b.bench("ops::dot d=512", || {
        black_box(ops::dot(&x, &y));
    });
    b.bench("ops::axpy d=512", || {
        ops::axpy(0.5, &x, &mut y);
        black_box(&y);
    });

    // ---- matvec family ----------------------------------------------
    let dense = shard.x.to_dense();
    let mut out_n = vec![0.0; n];
    let mut out_d = vec![0.0; d];
    b.bench("dense matvec 2048x512", || {
        dense.matvec(&x, &mut out_n);
        black_box(&out_n);
    });
    b.bench("dense rmatvec 2048x512", || {
        dense.rmatvec(&out_n, &mut out_d);
        black_box(&out_d);
    });

    let sparse_ds = dane::data::astro_like(2048, 8, 5);
    if let DataMatrix::Sparse(s) = &sparse_ds.x {
        let vs: Vec<f64> = (0..s.cols()).map(|_| 0.01).collect();
        let mut o = vec![0.0; s.rows()];
        let nnz = s.nnz();
        b.bench(&format!("csr matvec 2048x10000 (nnz={nnz})"), || {
            s.matvec(&vs, &mut o);
            black_box(&o);
        });
    }

    // ---- HVP operator (the CG inner step) ----------------------------
    let weights = vec![1.0; n];
    let hvp = ShardHvp::new(&shard, &weights, lam);
    b.bench("shard hvp (gram matvec) 2048x512", || {
        use dane::linalg::LinearOperator;
        hvp.apply(&x, &mut out_d);
        black_box(&out_d);
    });

    // ---- Gram + Cholesky (the cached local solver's setup + steady state)
    let t0 = std::time::Instant::now();
    let gram = dense.gram();
    println!("one-shot gram 2048x512 -> 512x512: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    let shifted = gram.add_diag(lam);
    let t0 = std::time::Instant::now();
    let chol = CholeskyFactor::factor(&shifted).unwrap();
    println!("one-shot cholesky d=512: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    let rhs: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
    b.bench("cholesky solve d=512 (steady-state DANE step)", || {
        black_box(chol.solve(&rhs));
    });

    // ---- CG local solve (the Hessian-free path) ----------------------
    let mut cgs = CgScratch::new(d);
    let mut sol = vec![0.0; d];
    b.bench("cg solve tol=1e-10 (hessian-free local solve)", || {
        cg_solve(&hvp, &rhs, &mut sol, 1e-10, 500, &mut cgs).unwrap();
        black_box(&sol);
    });

    // ---- worker-level DANE local solve -------------------------------
    let shards = shard_dataset(&ds, 1, 3);
    let mut worker = Worker::new(0, shards.into_iter().next().unwrap(), obj.clone());
    let w_prev = vec![0.0; d];
    let mut g = vec![0.0; d];
    worker.grad(&w_prev, &mut g).unwrap();
    // warm the factor cache, then measure steady-state
    worker.dane_local_solve(&w_prev, &g, 1.0, 0.0).unwrap();
    b.bench("worker dane_local_solve (cached cholesky)", || {
        black_box(worker.dane_local_solve(&w_prev, &g, 1.0, 0.0).unwrap());
    });

    // hinge local solve (Newton-CG) on covtype-like
    let hds = dane::data::covtype_like(2048, 8, 7);
    let hobj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(1e-3));
    let hshards = shard_dataset(&hds, 1, 3);
    let mut hworker = Worker::new(0, hshards.into_iter().next().unwrap(), hobj.clone());
    let hw_prev = vec![0.0; 54];
    let mut hg = vec![0.0; 54];
    hworker.grad(&hw_prev, &mut hg).unwrap();
    b.bench("worker hinge local solve (newton-cg) 2048x54", || {
        black_box(hworker.dane_local_solve(&hw_prev, &hg, 1.0, 3e-3).unwrap());
    });

    // ---- full DANE round, m = 8 --------------------------------------
    let big = synthetic_fig2(8192, 256, 0.005, 9);
    let obj2: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj2.as_ref(), &big.as_single_shard()).unwrap();
    let mut cluster = SerialCluster::new(&big, obj2, 8, 3);
    // warm caches
    let ctx = RunCtx::new(2).with_reference(phi_star).with_tol(0.0);
    dane::coordinator::dane::run(&mut cluster, &Default::default(), &ctx);
    let w = vec![0.0; 256];
    b.bench("cluster grad_and_loss m=8 N=8192 d=256", || {
        black_box(cluster.grad_and_loss(&w).unwrap());
    });
    let (g2, _) = cluster.eval_grad_loss(&w).unwrap();
    b.bench("cluster dane_round m=8 N=8192 d=256", || {
        black_box(cluster.dane_round(&w, &g2, 1.0, 0.0).unwrap());
    });

    // ---- PJRT artifact calls ------------------------------------------
    if let Ok(reg) = ArtifactRegistry::open(Path::new("artifacts")) {
        let reg = Arc::new(reg);
        let pj_ds = synthetic_fig2(2000, 500, 0.005, 21);
        let pj_shards = shard_dataset(&pj_ds, 1, 1);
        let pobj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let session =
            PjrtSession::for_shard(reg, &pj_shards[0], pobj.as_ref()).unwrap();
        let wv = vec![0.0; 500];
        let mut gv = vec![0.0; 500];
        // warm compile
        session.grad(&pj_shards[0], pobj.as_ref(), &wv, &mut gv).unwrap();
        b.bench("pjrt ridge_grad artifact (2048x512 padded)", || {
            black_box(
                session.grad(&pj_shards[0], pobj.as_ref(), &wv, &mut gv).unwrap(),
            );
        });
        session
            .dane_local_solve(&pj_shards[0], pobj.as_ref(), &wv, &gv, 1.0, 0.0)
            .unwrap();
        b.bench("pjrt ridge_local_solve artifact (CG in HLO)", || {
            black_box(
                session
                    .dane_local_solve(&pj_shards[0], pobj.as_ref(), &wv, &gv, 1.0, 0.0)
                    .unwrap(),
            );
        });
    } else {
        println!("(artifacts/ not built; skipping PJRT benches)");
    }

    println!("== hotpath_micro done ==");
}
