//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf).
//!
//! Criterion-style timing (in-tree harness, util::bench) of every
//! operation on the DANE hot path, bottom-up: vector kernels, dense and
//! sparse matvecs, Gram assembly, Cholesky factor/solve, CG, the cached
//! quadratic local solve, a full DANE round on both cluster engines, and
//! the PJRT artifact calls. The canonical shard is 2048 x 512 (matching
//! the AOT artifact shape).
//!
//! Kernel generations are benched **side by side** — the previous 2-row
//! Gram and unblocked Cholesky are kept in-tree precisely so every run
//! re-measures old vs new — and the whole run is serialized to
//! `BENCH_hotpath.json` at the repo root (see `Bencher::write_json`),
//! which is the machine-readable perf trajectory PR claims are checked
//! against. `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` shrink the run for
//! CI's bench-smoke job; `BENCH_LABEL` overrides the git label.

use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::{Cluster, RunCtx, SerialCluster};
use dane::data::{shard_dataset, synthetic_fig2};
use dane::linalg::cg::{cg_solve, CgScratch};
use dane::linalg::{ops, CholeskyFactor, DataMatrix};
use dane::loss::{Objective, Ridge, ShardHvp, SmoothHinge};
use dane::runtime::{ArtifactRegistry, PjrtSession};
use dane::solver::erm_solve;
use dane::util::bench::{black_box, git_label, Bencher};
use dane::util::Rng64;
use dane::worker::Worker;
use std::path::Path;
use std::sync::Arc;

/// Repo root (one above the cargo manifest), where the trajectory lands.
const BENCH_JSON: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_hotpath.json");

fn main() {
    let b = Bencher::from_env(900, 150, 40);
    println!("== hotpath_micro (canonical shard 2048x512) ==");

    let (n, d) = (2048usize, 512usize);
    let ds = synthetic_fig2(n, d, 0.005, 42);
    let shard = ds.as_single_shard();
    let lam = 0.01;
    let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));

    // ---- L0 vector kernels ------------------------------------------
    let mut rng = Rng64::seed_from_u64(1);
    let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let mut y: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    b.bench("ops::dot d=512", || {
        black_box(ops::dot(&x, &y));
    });
    b.bench("ops::axpy d=512", || {
        ops::axpy(0.5, &x, &mut y);
        black_box(&y);
    });

    // ---- matvec family ----------------------------------------------
    let dense = shard.x.to_dense();
    let mut out_n = vec![0.0; n];
    let mut out_d = vec![0.0; d];
    b.bench("dense matvec 2048x512", || {
        dense.matvec(&x, &mut out_n);
        black_box(&out_n);
    });
    b.bench("dense rmatvec 2048x512", || {
        dense.rmatvec(&out_n, &mut out_d);
        black_box(&out_d);
    });

    let sparse_ds = dane::data::astro_like(2048, 8, 5);
    if let DataMatrix::Sparse(s) = &sparse_ds.x {
        let vs: Vec<f64> = (0..s.cols()).map(|_| 0.01).collect();
        let mut o = vec![0.0; s.rows()];
        let nnz = s.nnz();
        b.bench(&format!("csr matvec 2048x10000 (nnz={nnz})"), || {
            s.matvec(&vs, &mut o);
            black_box(&o);
        });
    }

    // ---- HVP operator (the CG inner step) ----------------------------
    let weights = vec![1.0; n];
    let hvp = ShardHvp::new(&shard, &weights, lam);
    b.bench("shard hvp (gram matvec) 2048x512", || {
        use dane::linalg::LinearOperator;
        hvp.apply(&x, &mut out_d);
        black_box(&out_d);
    });

    // ---- Gram assembly: previous 2-row kernel vs tiled vs parallel ---
    b.bench("gram 2048x512 (2row)", || {
        black_box(dense.gram_2row());
    });
    b.bench("gram 2048x512 (blocked)", || {
        black_box(dense.gram());
    });
    b.bench("gram 2048x512 (parallel t=4)", || {
        black_box(dense.par_gram(4));
    });

    // ---- Cholesky: unblocked vs blocked right-looking ----------------
    let gram = dense.gram();
    let shifted = gram.add_diag(lam);
    b.bench("cholesky factor d=512 (unblocked)", || {
        black_box(CholeskyFactor::factor_unblocked(&shifted).unwrap());
    });
    b.bench("cholesky factor d=512 (blocked)", || {
        black_box(CholeskyFactor::factor(&shifted).unwrap());
    });
    let chol = CholeskyFactor::factor(&shifted).unwrap();
    let rhs: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
    let mut sol_buf = rhs.clone();
    b.bench("cholesky solve d=512 (steady-state DANE step)", || {
        sol_buf.copy_from_slice(&rhs);
        chol.solve_in_place(&mut sol_buf);
        black_box(&sol_buf);
    });

    // ---- CG local solve (the Hessian-free path) ----------------------
    let mut cgs = CgScratch::new(d);
    let mut sol = vec![0.0; d];
    b.bench("cg solve tol=1e-10 (hessian-free local solve)", || {
        cg_solve(&hvp, &rhs, &mut sol, 1e-10, 500, &mut cgs).unwrap();
        black_box(&sol);
    });

    // ---- worker-level DANE local solve -------------------------------
    let shards = shard_dataset(&ds, 1, 3);
    let mut worker = Worker::new(0, shards.into_iter().next().unwrap(), obj.clone());
    let w_prev = vec![0.0; d];
    let mut g = vec![0.0; d];
    worker.grad(&w_prev, &mut g).unwrap();
    // warm the factor cache, then measure steady-state (allocation-free)
    let mut local = Vec::new();
    worker.dane_local_solve_into(&w_prev, &g, 1.0, 0.0, &mut local).unwrap();
    b.bench("worker dane_local_solve (cached cholesky)", || {
        worker.dane_local_solve_into(&w_prev, &g, 1.0, 0.0, &mut local).unwrap();
        black_box(&local);
    });

    // hinge local solve (Newton-CG) on covtype-like
    let hds = dane::data::covtype_like(2048, 8, 7);
    let hobj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(1e-3));
    let hshards = shard_dataset(&hds, 1, 3);
    let mut hworker = Worker::new(0, hshards.into_iter().next().unwrap(), hobj.clone());
    let hw_prev = vec![0.0; 54];
    let mut hg = vec![0.0; 54];
    hworker.grad(&hw_prev, &mut hg).unwrap();
    b.bench("worker hinge local solve (newton-cg) 2048x54", || {
        black_box(hworker.dane_local_solve(&hw_prev, &hg, 1.0, 3e-3).unwrap());
    });

    // ---- full DANE round, m = 8, both engines ------------------------
    let big = synthetic_fig2(8192, 256, 0.005, 9);
    let obj2: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
    let (_, phi_star) = erm_solve(obj2.as_ref(), &big.as_single_shard()).unwrap();
    let mut cluster = SerialCluster::new(&big, obj2.clone(), 8, 3);
    // warm caches
    let ctx = RunCtx::new(2).with_reference(phi_star).with_tol(0.0);
    dane::coordinator::dane::run(&mut cluster, &Default::default(), &ctx).expect("warmup");
    let w = vec![0.0; 256];
    b.bench("cluster grad_and_loss m=8 N=8192 d=256", || {
        black_box(cluster.grad_and_loss(&w).unwrap());
    });
    let (g2, _) = cluster.eval_grad_loss(&w).unwrap();
    b.bench("cluster dane_round m=8 N=8192 d=256", || {
        black_box(cluster.dane_round(&w, &g2, 1.0, 0.0).unwrap());
    });

    // threaded engine, zero-allocation protocol, in-place collectives
    let mut tcluster = ThreadedCluster::new(&big, obj2, 8, 3);
    let mut tg = vec![0.0; 256];
    let mut tout = vec![0.0; 256];
    tcluster.grad_and_loss_into(&w, &mut tg).unwrap();
    tcluster.dane_round_into(&w, &tg, 1.0, 0.0, &mut tout).unwrap(); // warm factors
    b.bench("threaded grad_and_loss m=8 N=8192 d=256", || {
        black_box(tcluster.grad_and_loss_into(&w, &mut tg).unwrap());
    });
    b.bench("threaded dane_round m=8 N=8192 d=256", || {
        tcluster.dane_round_into(&w, &tg, 1.0, 0.0, &mut tout).unwrap();
        black_box(&tout);
    });

    // ---- PJRT artifact calls ------------------------------------------
    if let Ok(reg) = ArtifactRegistry::open(Path::new("artifacts")) {
        let reg = Arc::new(reg);
        let pj_ds = synthetic_fig2(2000, 500, 0.005, 21);
        let pj_shards = shard_dataset(&pj_ds, 1, 1);
        let pobj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let session =
            PjrtSession::for_shard(reg, &pj_shards[0], pobj.as_ref()).unwrap();
        let wv = vec![0.0; 500];
        let mut gv = vec![0.0; 500];
        // warm compile
        session.grad(&pj_shards[0], pobj.as_ref(), &wv, &mut gv).unwrap();
        b.bench("pjrt ridge_grad artifact (2048x512 padded)", || {
            black_box(
                session.grad(&pj_shards[0], pobj.as_ref(), &wv, &mut gv).unwrap(),
            );
        });
        session
            .dane_local_solve(&pj_shards[0], pobj.as_ref(), &wv, &gv, 1.0, 0.0)
            .unwrap();
        b.bench("pjrt ridge_local_solve artifact (CG in HLO)", || {
            black_box(
                session
                    .dane_local_solve(&pj_shards[0], pobj.as_ref(), &wv, &gv, 1.0, 0.0)
                    .unwrap(),
            );
        });
    } else {
        println!("(artifacts/ not built; skipping PJRT benches)");
    }

    // ---- old-vs-new summary + JSON trajectory -------------------------
    let speedup = |old: &str, new: &str| -> Option<f64> {
        Some(b.median_ns_of(old)? / b.median_ns_of(new)?)
    };
    if let Some(s) = speedup("gram 2048x512 (2row)", "gram 2048x512 (blocked)") {
        println!("speedup gram 2048x512 (2row -> blocked):        {s:.2}x");
    }
    if let Some(s) = speedup("gram 2048x512 (2row)", "gram 2048x512 (parallel t=4)") {
        println!("speedup gram 2048x512 (2row -> parallel t=4):   {s:.2}x");
    }
    if let Some(s) = speedup(
        "cholesky factor d=512 (unblocked)",
        "cholesky factor d=512 (blocked)",
    ) {
        println!("speedup cholesky factor d=512 (unblocked -> blocked): {s:.2}x");
    }

    let json_path = Path::new(BENCH_JSON);
    match b.write_json(json_path, "hotpath_micro", &git_label()) {
        Ok(()) => println!("wrote {}", json_path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", json_path.display()),
    }
    println!("== hotpath_micro done ==");
}
