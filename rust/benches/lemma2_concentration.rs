//! LEMMA2 bench: empirical max_i ||H_i - H||_2 against the
//! sqrt(32 L^2 log(dm/delta) / n) concentration bound, sweeping the
//! per-machine sample count. The measured deviation must shrink ~1/sqrt(n)
//! and stay below the bound.

fn main() {
    println!("== lemma2 bench ==");
    let t0 = std::time::Instant::now();
    let rows = dane::harness::lemma2().expect("lemma2 harness");
    // 64x more data -> ~8x smaller deviation; accept >= 4x.
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    let shrink = first.max_dev / last.max_dev;
    println!(
        "n {}x -> deviation shrank {shrink:.1}x (sqrt predicts {:.1}x)",
        last.n_per_machine / first.n_per_machine,
        ((last.n_per_machine / first.n_per_machine) as f64).sqrt()
    );
    assert!(shrink > 4.0, "Lemma 2 rate violated: {shrink:.2}x");
    println!("lemma2 bench done in {:.1}s", t0.elapsed().as_secs_f64());
}
