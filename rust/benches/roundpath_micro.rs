//! Round-path micro-benchmarks (EXPERIMENTS.md §Perf): the full DANE
//! round — `grad_and_loss_into` + `dane_round_into`, i.e. two
//! broadcast/fold collectives — measured end to end across the engine ×
//! topology matrix at m in {4, 8, 16}, plus **measured leader-thread
//! allocations per round** from a counting global allocator.
//!
//! Two families of entries:
//!
//! * `dane round m=<m> <topology> <engine>` — median latency of one
//!   full round. Shards are small (64 rows per worker), so the number
//!   is dominated by what the round path exists to move: frames,
//!   channel hops and the leader's fan-out/fan-in + rank-order fold.
//! * `leader allocs/round m=<m> <topology> <engine>` — allocator hits
//!   on the leader thread per steady-state round (value column, not
//!   nanoseconds). The star strategies must report **0.0** on both
//!   engines — that is the same contract
//!   `rust/tests/alloc_steady_state.rs` pins as a hard assert; this
//!   file records it as a trajectory so CI's regression gate catches a
//!   reintroduced per-round allocation as a >1.5x jump (any value > 0
//!   against a 0 baseline fails the gate). `star-seq` on tcp decodes
//!   replies inline on the leader thread and the tree wirings allocate
//!   their relay bundles — those counts are small constants, recorded
//!   so drift is visible, not pinned to zero (coordinator::tcp module
//!   docs, "Allocation-free round path").
//!
//! TCP workers are in-process threads serving the genuine
//! `worker::serve` session over loopback sockets (same frames, relays
//! and bundles as worker processes, minus spawn noise); their
//! allocations land in their own thread-local counters, so the leader
//! count isolates exactly the protocol path. The run serializes to
//! `BENCH_roundpath.json` at the repo root (`dane-bench-v1` schema);
//! `BENCH_MEASURE_MS` / `BENCH_WARMUP_MS` shrink it for CI bench-smoke.

use dane::comm::{ExecTopology, NetModel};
use dane::config::LossKind;
use dane::coordinator::tcp::TcpCluster;
use dane::coordinator::threaded::ThreadedCluster;
use dane::coordinator::Cluster;
use dane::data::{synthetic_fig2, Dataset};
use dane::loss::{Objective, Ridge};
use dane::util::bench::{black_box, git_label, Bencher};
use dane::worker::serve;
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::net::TcpListener;
use std::sync::Arc;

/// Repo root (one above the cargo manifest), where the trajectory lands.
const BENCH_JSON: &str =
    concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_roundpath.json");

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

// SAFETY: delegates to System; the thread-local bump never allocates
// (const-initialized Cell) and tolerates TLS teardown via try_with.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn leader_allocs() -> u64 {
    ALLOCS.with(|c| c.get())
}

/// See wire_micro: loopback listeners served by in-process threads.
fn spawn_inprocess_workers(m: usize) -> Vec<String> {
    let mut addrs = Vec::with_capacity(m);
    for _ in 0..m {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().expect("local addr").to_string());
        std::thread::spawn(move || {
            let _ = serve::serve_listener(listener);
        });
    }
    addrs
}

fn tcp_cluster(ds: &Dataset, m: usize, topology: ExecTopology) -> TcpCluster {
    let addrs = spawn_inprocess_workers(m);
    TcpCluster::connect(
        ds,
        LossKind::Ridge,
        0.01,
        &addrs,
        7,
        NetModel::free(),
        None,
        None,
        topology,
    )
    .expect("tcp cluster over in-process workers")
}

/// Bench one cluster: round latency + steady-state leader allocations.
fn bench_round_path<C: Cluster>(
    b: &Bencher,
    cluster: &mut C,
    d: usize,
    m: usize,
    topo: ExecTopology,
    engine: &str,
) {
    let mut w = vec![0.0; d];
    let mut w_next = vec![0.0; d];
    let mut g = vec![0.0; d];

    // Warmup: one-time state (worker caches, pooled frames/gathers).
    for _ in 0..3 {
        cluster.grad_and_loss_into(&w, &mut g).expect("warmup grad");
        cluster
            .dane_round_into(&w, &g, 1.0, 0.01, &mut w_next)
            .expect("warmup solve");
        std::mem::swap(&mut w, &mut w_next);
    }

    b.bench(&format!("dane round m={m} {} {engine}", topo.name()), || {
        cluster.grad_and_loss_into(&w, &mut g).expect("grad round");
        cluster
            .dane_round_into(&w, &g, 1.0, 0.01, &mut w_next)
            .expect("solve round");
        black_box(&w_next);
    });

    const COUNT_ROUNDS: u64 = 32;
    let before = leader_allocs();
    for _ in 0..COUNT_ROUNDS {
        cluster.grad_and_loss_into(&w, &mut g).expect("count grad");
        cluster
            .dane_round_into(&w, &g, 1.0, 0.01, &mut w_next)
            .expect("count solve");
        std::mem::swap(&mut w, &mut w_next);
    }
    let per_round = (leader_allocs() - before) as f64 / COUNT_ROUNDS as f64;
    b.record_value(
        &format!("leader allocs/round m={m} {} {engine}", topo.name()),
        per_round,
    );
}

fn main() {
    let b = Bencher::from_env(500, 100, 40);
    println!("== roundpath_micro (full DANE round; m in {{4,8,16}}) ==");

    let d = 64usize;
    let strategies =
        [ExecTopology::StarSeq, ExecTopology::Star, ExecTopology::Tree];
    for m in [4usize, 8, 16] {
        // 64 rows per worker: compute stays negligible next to the
        // round path under measurement.
        let ds = synthetic_fig2(64 * m, d, 0.005, 42);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        for topo in strategies {
            let mut threaded = ThreadedCluster::with_topology(
                &ds,
                obj.clone(),
                m,
                7,
                NetModel::free(),
                None,
                topo,
            );
            bench_round_path(&b, &mut threaded, d, m, topo, "threaded");
            drop(threaded);

            let mut tcp = tcp_cluster(&ds, m, topo);
            bench_round_path(&b, &mut tcp, d, m, topo, "tcp");
        }
    }

    // Zero-alloc contract echo (the hard assert lives in
    // tests/alloc_steady_state.rs; here it is a visible summary).
    for m in [4usize, 8, 16] {
        for engine in ["threaded", "tcp"] {
            if let Some(v) =
                b.median_ns_of(&format!("leader allocs/round m={m} star {engine}"))
            {
                println!("m={m:<3} {engine:<8} star leader allocs/round: {v}");
            }
        }
    }

    b.write_json(
        std::path::Path::new(BENCH_JSON),
        "roundpath_micro",
        &git_label(),
    )
    .expect("write BENCH_roundpath.json");
    println!("wrote {BENCH_JSON}");
}
