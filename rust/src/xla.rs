//! Offline PJRT/xla stub.
//!
//! The runtime bridge ([`crate::runtime`]) was written against the
//! `xla` PJRT bindings, which the offline build cannot vendor. This
//! module keeps the exact API surface the bridge uses so the crate
//! builds and tests with zero external dependencies:
//!
//! * [`Literal`] is a *real* host-side tensor (f32 buffer + dims) — the
//!   marshalling layer in [`crate::runtime::literal`] and its unit tests
//!   run against it unchanged;
//! * [`PjRtClient::cpu`] fails with a clear error, so every artifact
//!   path degrades at *runtime* (callers fall back to the native
//!   backend or skip), never at compile time.
//!
//! Swapping a real PJRT binding back in is a one-line change: delete
//! the `use crate::xla;` aliases and add the dependency.

use std::fmt;

/// Error type mirroring the binding's.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime is unavailable in this offline build; \
         use the native worker backend"
            .into(),
    ))
}

/// Host-side tensor: f32 data + dims, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Rank-1 literal from an f32 slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: v.to_vec(), tuple: None }
    }

    /// Rank-0 scalar literal.
    pub fn scalar(x: f32) -> Literal {
        Literal { dims: Vec::new(), data: vec![x], tuple: None }
    }

    /// Reinterpret the buffer under new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let count: i64 = dims.iter().product();
        if self.tuple.is_some() || count as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone(), tuple: None })
    }

    /// The flat f32 buffer (row-major).
    pub fn to_vec(&self) -> Result<Vec<f32>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        Ok(self.data.clone())
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Unwrap a 1-tuple.
    pub fn to_tuple1(self) -> Result<Literal> {
        match self.tuple {
            Some(mut t) if t.len() == 1 => Ok(t.pop().unwrap()),
            _ => Err(Error("expected a 1-tuple literal".into())),
        }
    }

    /// Unwrap a 2-tuple.
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        match self.tuple {
            Some(mut t) if t.len() == 2 => {
                let b = t.pop().unwrap();
                let a = t.pop().unwrap();
                Ok((a, b))
            }
            _ => Err(Error("expected a 2-tuple literal".into())),
        }
    }
}

/// Parsed HLO module handle (never constructible offline).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// Computation handle built from a proto.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. `cpu()` always fails offline.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> &'static str {
        "offline-stub"
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_shapes_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.to_vec().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(Literal::scalar(7.5).to_vec().unwrap(), vec![7.5]);
    }

    #[test]
    fn tuple_accessors_reject_non_tuples() {
        assert!(Literal::vec1(&[1.0]).to_tuple1().is_err());
        assert!(Literal::scalar(0.0).to_tuple2().is_err());
    }

    #[test]
    fn client_is_unavailable_offline() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("offline"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
