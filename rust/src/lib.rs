//! # DANE — Distributed Approximate NEwton-type optimization
//!
//! A production-shaped reproduction of *"Communication Efficient Distributed
//! Optimization using an Approximate Newton-type Method"* (Shamir, Srebro,
//! Zhang — ICML 2014).
//!
//! The crate is the **L3 coordinator** of a three-layer stack:
//!
//! * **L3 (here, rust)** — leader/worker round engine with three
//!   transports over one typed wire protocol ([`comm::wire`]): inline
//!   (`SerialCluster`), OS threads (`ThreadedCluster`) and real TCP
//!   worker processes (`TcpCluster`, with measured `wire_bytes`
//!   accounting); simulated collective layer with communication
//!   accounting, DANE and every baseline the paper compares against
//!   (GD, accelerated GD, consensus ADMM, one-shot averaging ± bias
//!   correction, distributed L-BFGS), data generators, losses, local
//!   solvers, metrics and a CLI launcher.
//! * **L2 (jax, build-time)** — the per-worker compute graphs
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! * **L1 (pallas, build-time)** — the tiled Gram-matvec and fused
//!   smooth-hinge kernels the L2 graphs call.
//!
//! Workers can execute their local computations either natively (pure-rust
//! [`linalg`]) or through the AOT artifacts via the PJRT bridge in
//! [`runtime`]; integration tests pin the two backends against each other.
//!
//! ## Quick start
//!
//! ```no_run
//! use dane::prelude::*;
//! use std::sync::Arc;
//!
//! // 16k synthetic ridge samples split over 16 workers (paper fig. 2 setup)
//! let ds = dane::data::synthetic_fig2(16_384, 500, 0.005, 42);
//! let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
//! let mut cluster = SerialCluster::new(&ds, obj, 16, 42);
//! let opts = DaneOptions { eta: 1.0, mu: 0.0, ..Default::default() };
//! let ctx = dane::coordinator::RunCtx::new(20);
//! // Algorithms run on any `Cluster` engine (SerialCluster here,
//! // ThreadedCluster for one OS thread per worker) and return a
//! // Result: a dead worker surfaces as Err with the trace-so-far,
//! // never a panic.
//! let run = dane::coordinator::dane::run(&mut cluster, &opts, &ctx).expect("run");
//! println!("final suboptimality: {:?}", run.trace.last_suboptimality());
//! ```
//!
//! See `examples/` for runnable end-to-end drivers and `rust/benches/` for
//! the harnesses that regenerate every table and figure in the paper.
//!
//! ## Safety policy
//!
//! The crate is `#![forbid(unsafe_code)]`: every transport, codec and
//! solver is safe Rust, so the "no panic reachable from a worker
//! failure or a hostile byte stream" invariant can be audited at the
//! source level (and is — see [`analysis`], the in-tree `dane-lint`
//! pass that CI runs). The only `unsafe` in the repository is the
//! counting `GlobalAlloc` inside `tests/alloc_steady_state.rs` and its
//! twin in `benches/roundpath_micro.rs` — test/bench binaries that pin
//! the allocation-free steady-state round path, not part of this crate.

#![forbid(unsafe_code)]

pub mod analysis;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod harness;
pub mod linalg;
pub mod loss;
pub mod metrics;
pub mod runtime;
pub mod solver;
pub mod util;
pub mod worker;
pub mod xla;

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::comm::{CommStats, ExecTopology, NetModel, Topology};
    pub use crate::config::{AlgoConfig, DatasetConfig, EngineKind, ExperimentConfig};
    pub use crate::coordinator::admm::AdmmOptions;
    pub use crate::coordinator::dane::DaneOptions;
    pub use crate::coordinator::driver::{run_experiment, RunResult};
    pub use crate::coordinator::fault::FaultInjectCluster;
    pub use crate::coordinator::gd::{AgdOptions, GdOptions};
    pub use crate::coordinator::tcp::TcpCluster;
    pub use crate::coordinator::threaded::ThreadedCluster;
    pub use crate::coordinator::{AlgoError, AlgoOutcome, AlgoResult, Cluster, SerialCluster};
    pub use crate::data::{Dataset, Shard};
    pub use crate::linalg::{CsrMatrix, DataMatrix, DenseMatrix};
    pub use crate::loss::{Objective, Ridge, SmoothHinge};
    pub use crate::metrics::Trace;
    pub use crate::worker::Worker;
}
