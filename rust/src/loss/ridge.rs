//! Ridge (regularized least squares) — the paper's fig. 2 objective.
//!
//! `phi_i(w) = (1/2n) ||X w - y||^2 + (lam/2) ||w||^2`.
//!
//! Quadratic: the Hessian `(1/n) X^T X + lam I` is constant, so DANE's
//! local problem has the closed form of paper eq. (16) and the local
//! solver can cache a Cholesky factorization across rounds.

use super::traits::Objective;
use crate::data::Shard;
use crate::linalg::ops;

#[derive(Debug, Clone, Copy)]
pub struct Ridge {
    lam: f64,
}

impl Ridge {
    pub fn new(lam: f64) -> Self {
        assert!(lam >= 0.0, "lambda must be nonnegative");
        Ridge { lam }
    }
}

impl Objective for Ridge {
    fn name(&self) -> &'static str {
        "ridge"
    }

    fn lambda(&self) -> f64 {
        self.lam
    }

    fn is_quadratic(&self) -> bool {
        true
    }

    fn value(&self, shard: &Shard, w: &[f64], rowbuf: &mut [f64]) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("ridge value matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let r = rowbuf[j] - shard.y[j];
            acc += r * r;
        }
        acc / (2.0 * n) + 0.5 * self.lam * ops::dot(w, w)
    }

    fn value_grad(
        &self,
        shard: &Shard,
        w: &[f64],
        out: &mut [f64],
        rowbuf: &mut [f64],
    ) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("ridge grad matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let r = rowbuf[j] - shard.y[j];
            acc += r * r;
            rowbuf[j] = r / n;
        }
        shard.x.rmatvec(rowbuf, out).expect("ridge grad rmatvec");
        ops::axpy(self.lam, w, out);
        acc / (2.0 * n) + 0.5 * self.lam * ops::dot(w, w)
    }

    fn hess_weights(&self, shard: &Shard, _w: &[f64], out: &mut [f64]) {
        // l'' = 1 everywhere except padding rows (zero feature rows
        // contribute nothing anyway, but keeping them at 1 is harmless
        // because X row = 0 annihilates the weight).
        out[..shard.n()].fill(1.0);
    }

    fn scalar_smoothness(&self) -> f64 {
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::{grad_check, reg_shard};

    #[test]
    fn gradient_matches_finite_difference() {
        let shard = reg_shard(40, 7, 3);
        let obj = Ridge::new(0.05);
        let w: Vec<f64> = (0..7).map(|i| 0.3 * (i as f64) - 1.0).collect();
        assert!(grad_check(&obj, &shard, &w) < 1e-6);
    }

    #[test]
    fn value_at_zero_is_mean_square() {
        let shard = reg_shard(10, 3, 1);
        let obj = Ridge::new(0.0);
        let mut rowbuf = vec![0.0; 10];
        let v = obj.value(&shard, &[0.0; 3], &mut rowbuf);
        let expect: f64 =
            shard.y.iter().map(|y| y * y).sum::<f64>() / (2.0 * 10.0);
        assert!((v - expect).abs() < 1e-12);
    }

    #[test]
    fn regularizer_adds_quadratic() {
        let shard = reg_shard(10, 3, 1);
        let w = vec![1.0, -2.0, 0.5];
        let mut rowbuf = vec![0.0; 10];
        let v0 = Ridge::new(0.0).value(&shard, &w, &mut rowbuf);
        let v1 = Ridge::new(2.0).value(&shard, &w, &mut rowbuf);
        let wsq: f64 = w.iter().map(|x| x * x).sum();
        assert!((v1 - v0 - wsq).abs() < 1e-12);
    }

    #[test]
    fn value_grad_consistent_with_value() {
        let shard = reg_shard(25, 4, 9);
        let obj = Ridge::new(0.1);
        let w = vec![0.2, -0.4, 1.0, 0.0];
        let mut rowbuf = vec![0.0; 25];
        let mut g = vec![0.0; 4];
        let v1 = obj.value_grad(&shard, &w, &mut g, &mut rowbuf);
        let v2 = obj.value(&shard, &w, &mut rowbuf);
        assert!((v1 - v2).abs() < 1e-12);
    }
}
