//! Regularized logistic loss — an extra smooth non-quadratic objective
//! beyond the paper's experiments (the paper's framework covers any smooth
//! strongly convex objective; logistic is the standard extension and gives
//! the test suite a loss with strictly positive curvature everywhere).
//!
//! `l(a) = ln(1 + exp(-a))`, margin `a = y <x, w>`.

use super::traits::Objective;
use crate::data::Shard;
use crate::linalg::ops;

#[derive(Debug, Clone, Copy)]
pub struct Logistic {
    lam: f64,
}

impl Logistic {
    pub fn new(lam: f64) -> Self {
        assert!(lam >= 0.0, "lambda must be nonnegative");
        Logistic { lam }
    }

    /// Numerically stable ln(1 + e^{-a}).
    #[inline]
    pub fn loss(a: f64) -> f64 {
        if a > 0.0 {
            (-a).exp().ln_1p()
        } else {
            -a + a.exp().ln_1p()
        }
    }

    /// l'(a) = -sigma(-a)
    #[inline]
    pub fn dloss(a: f64) -> f64 {
        -1.0 / (1.0 + a.exp())
    }

    /// l''(a) = sigma(a) sigma(-a)
    #[inline]
    pub fn ddloss(a: f64) -> f64 {
        let s = 1.0 / (1.0 + (-a).exp());
        s * (1.0 - s)
    }
}

impl Objective for Logistic {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn lambda(&self) -> f64 {
        self.lam
    }

    fn is_quadratic(&self) -> bool {
        false
    }

    fn value(&self, shard: &Shard, w: &[f64], rowbuf: &mut [f64]) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("logistic value matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let yj = shard.y[j];
            if yj != 0.0 {
                acc += Self::loss(yj * rowbuf[j]);
            }
        }
        acc / n + 0.5 * self.lam * ops::dot(w, w)
    }

    fn value_grad(
        &self,
        shard: &Shard,
        w: &[f64],
        out: &mut [f64],
        rowbuf: &mut [f64],
    ) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("logistic grad matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let yj = shard.y[j];
            if yj != 0.0 {
                let a = yj * rowbuf[j];
                acc += Self::loss(a);
                rowbuf[j] = Self::dloss(a) * yj / n;
            } else {
                rowbuf[j] = 0.0;
            }
        }
        shard.x.rmatvec(rowbuf, out).expect("logistic grad rmatvec");
        ops::axpy(self.lam, w, out);
        acc / n + 0.5 * self.lam * ops::dot(w, w)
    }

    fn hess_weights(&self, shard: &Shard, w: &[f64], out: &mut [f64]) {
        shard.x.matvec(w, out).expect("logistic weights matvec");
        for j in 0..shard.n() {
            let yj = shard.y[j];
            out[j] = if yj != 0.0 { Self::ddloss(yj * out[j]) } else { 0.0 };
        }
    }

    fn scalar_smoothness(&self) -> f64 {
        0.25
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::{class_shard, grad_check};

    #[test]
    fn stable_at_extreme_margins() {
        assert!(Logistic::loss(800.0).is_finite());
        assert!(Logistic::loss(-800.0).is_finite());
        assert!((Logistic::loss(-800.0) - 800.0).abs() < 1e-9);
        assert!(Logistic::loss(800.0) < 1e-9);
    }

    #[test]
    fn derivative_identities() {
        for &a in &[-3.0, -0.5, 0.0, 0.5, 3.0] {
            let eps = 1e-6;
            let fd = (Logistic::loss(a + eps) - Logistic::loss(a - eps)) / (2.0 * eps);
            assert!((fd - Logistic::dloss(a)).abs() < 1e-8);
            let fdd = (Logistic::dloss(a + eps) - Logistic::dloss(a - eps)) / (2.0 * eps);
            assert!((fdd - Logistic::ddloss(a)).abs() < 1e-6);
        }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let shard = class_shard(50, 5, 17);
        let obj = Logistic::new(0.02);
        let w: Vec<f64> = (0..5).map(|i| 0.1 * (i as f64)).collect();
        assert!(grad_check(&obj, &shard, &w) < 1e-6);
    }

    #[test]
    fn curvature_bounded_by_quarter() {
        let shard = class_shard(20, 3, 2);
        let obj = Logistic::new(0.0);
        let mut weights = vec![0.0; 20];
        obj.hess_weights(&shard, &[0.5, -0.5, 0.0], &mut weights);
        for &v in &weights {
            assert!(v > 0.0 && v <= 0.25 + 1e-12);
        }
    }
}
