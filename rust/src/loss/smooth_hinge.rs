//! Smooth hinge loss (Shalev-Shwartz & Zhang 2013) — figs. 3 and 4.
//!
//! With margin `a = y <x, w>` and smoothing `gamma` (paper-default 1):
//!
//! ```text
//! l(a)  = 0                      a >= 1
//!       = 1 - a - gamma/2        a <= 1 - gamma
//!       = (1 - a)^2 / (2 gamma)  otherwise
//! ```
//!
//! Piecewise-quadratic: l' is piecewise linear and l'' is 0 or 1/gamma,
//! so Newton-CG local solves converge in a handful of steps. Matches
//! `python/compile/kernels/ref.py` exactly.

use super::traits::Objective;
use crate::data::Shard;
use crate::linalg::ops;

#[derive(Debug, Clone, Copy)]
pub struct SmoothHinge {
    lam: f64,
    gamma: f64,
}

impl SmoothHinge {
    /// Paper-default smoothing gamma = 1.
    pub fn new(lam: f64) -> Self {
        Self::with_gamma(lam, 1.0)
    }

    pub fn with_gamma(lam: f64, gamma: f64) -> Self {
        assert!(lam >= 0.0, "lambda must be nonnegative");
        assert!(gamma > 0.0, "gamma must be positive");
        SmoothHinge { lam, gamma }
    }

    #[inline]
    pub fn loss(&self, a: f64) -> f64 {
        if a >= 1.0 {
            0.0
        } else if a <= 1.0 - self.gamma {
            1.0 - a - self.gamma / 2.0
        } else {
            (1.0 - a) * (1.0 - a) / (2.0 * self.gamma)
        }
    }

    #[inline]
    pub fn dloss(&self, a: f64) -> f64 {
        if a >= 1.0 {
            0.0
        } else if a <= 1.0 - self.gamma {
            -1.0
        } else {
            -(1.0 - a) / self.gamma
        }
    }

    #[inline]
    pub fn ddloss(&self, a: f64) -> f64 {
        if a < 1.0 && a > 1.0 - self.gamma {
            1.0 / self.gamma
        } else {
            0.0
        }
    }
}

impl Objective for SmoothHinge {
    fn name(&self) -> &'static str {
        "smooth_hinge"
    }

    fn lambda(&self) -> f64 {
        self.lam
    }

    fn is_quadratic(&self) -> bool {
        false
    }

    fn value(&self, shard: &Shard, w: &[f64], rowbuf: &mut [f64]) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("hinge value matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let yj = shard.y[j];
            if yj != 0.0 {
                acc += self.loss(yj * rowbuf[j]);
            }
        }
        acc / n + 0.5 * self.lam * ops::dot(w, w)
    }

    fn value_grad(
        &self,
        shard: &Shard,
        w: &[f64],
        out: &mut [f64],
        rowbuf: &mut [f64],
    ) -> f64 {
        let n = shard.n_effective() as f64;
        shard.x.matvec(w, rowbuf).expect("hinge grad matvec");
        let mut acc = 0.0;
        for j in 0..shard.n() {
            let yj = shard.y[j];
            if yj != 0.0 {
                let a = yj * rowbuf[j];
                acc += self.loss(a);
                rowbuf[j] = self.dloss(a) * yj / n;
            } else {
                rowbuf[j] = 0.0; // padding rows contribute nothing
            }
        }
        shard.x.rmatvec(rowbuf, out).expect("hinge grad rmatvec");
        ops::axpy(self.lam, w, out);
        acc / n + 0.5 * self.lam * ops::dot(w, w)
    }

    fn hess_weights(&self, shard: &Shard, w: &[f64], out: &mut [f64]) {
        shard.x.matvec(w, out).expect("hinge weights matvec");
        for j in 0..shard.n() {
            let yj = shard.y[j];
            // y^2 = 1 on real rows, 0 on padding — matches the L1 kernel.
            out[j] = if yj != 0.0 { self.ddloss(yj * out[j]) } else { 0.0 };
        }
    }

    fn scalar_smoothness(&self) -> f64 {
        1.0 / self.gamma
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::{class_shard, grad_check};

    #[test]
    fn pieces_join_continuously() {
        let h = SmoothHinge::with_gamma(0.0, 1.0);
        // value continuity at the knots
        assert!((h.loss(1.0) - 0.0).abs() < 1e-12);
        assert!((h.loss(0.0) - 0.5).abs() < 1e-12);
        // derivative continuity at the knots
        assert!((h.dloss(1.0) - 0.0).abs() < 1e-12);
        assert!((h.dloss(0.0) - (-1.0)).abs() < 1e-12);
        // linear tail
        assert!((h.loss(-2.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn gamma_half_knots() {
        let h = SmoothHinge::with_gamma(0.0, 0.5);
        assert!((h.loss(0.5) - 0.25).abs() < 1e-12);
        assert!((h.dloss(0.5) + 1.0).abs() < 1e-12);
        assert_eq!(h.ddloss(0.75), 2.0);
        assert_eq!(h.ddloss(0.25), 0.0);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let shard = class_shard(60, 6, 5);
        let obj = SmoothHinge::new(0.01);
        let w: Vec<f64> = (0..6).map(|i| 0.2 * (i as f64) - 0.5).collect();
        assert!(grad_check(&obj, &shard, &w) < 1e-6);
    }

    #[test]
    fn padding_rows_are_inert() {
        use crate::data::Shard;
        use crate::linalg::{DataMatrix, DenseMatrix};
        let x1 = DenseMatrix::from_rows(&[vec![1.0, -1.0], vec![0.5, 2.0]]);
        let mut rows = vec![x1.row(0).to_vec(), x1.row(1).to_vec()];
        rows.push(vec![0.0, 0.0]); // padding row
        let x2 = DenseMatrix::from_rows(&rows);
        let s1 = Shard::new(DataMatrix::Dense(x1), vec![1.0, -1.0]);
        let s2 = Shard::with_padding(DataMatrix::Dense(x2), vec![1.0, -1.0, 0.0], 2);
        let obj = SmoothHinge::new(0.1);
        let w = vec![0.3, -0.7];
        let mut b1 = vec![0.0; 2];
        let mut b2 = vec![0.0; 3];
        let mut g1 = vec![0.0; 2];
        let mut g2 = vec![0.0; 2];
        let v1 = obj.value_grad(&s1, &w, &mut g1, &mut b1);
        let v2 = obj.value_grad(&s2, &w, &mut g2, &mut b2);
        assert!((v1 - v2).abs() < 1e-14);
        assert!((g1[0] - g2[0]).abs() < 1e-14);
        assert!((g1[1] - g2[1]).abs() < 1e-14);
    }

    #[test]
    fn hess_weights_piecewise() {
        let shard = class_shard(30, 4, 8);
        let obj = SmoothHinge::new(0.0);
        let w = vec![0.1; 4];
        let mut weights = vec![0.0; 30];
        obj.hess_weights(&shard, &w, &mut weights);
        for &v in &weights {
            assert!(v == 0.0 || v == 1.0);
        }
    }
}
