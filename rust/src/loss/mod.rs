//! Regularized empirical objectives.
//!
//! Every objective has the generalized-linear form the paper studies:
//!
//! ```text
//! phi_i(w) = (1/n) sum_j l(r_j(w)) + (lam/2) ||w||^2
//! ```
//!
//! with `r_j` either the ridge residual `<x_j,w> - y_j` or the
//! classification margin `y_j <x_j,w>`. Gradients and Hessian-vector
//! products are therefore one streamed pass over the shard matrix —
//! exactly the structure the L1 Pallas kernels implement, and O(nnz) on
//! sparse shards.
//!
//! Objectives match `python/compile/model.py` definition-for-definition;
//! the PJRT-vs-native integration tests rely on that.

pub mod logistic;
pub mod ridge;
pub mod smooth_hinge;
pub mod traits;

pub use logistic::Logistic;
pub use ridge::Ridge;
pub use smooth_hinge::SmoothHinge;
pub use traits::{Objective, ShardHvp};

use crate::config::LossKind;
use std::sync::Arc;

/// Instantiate an objective from its config enum.
pub fn make_objective(kind: LossKind, lam: f64) -> Arc<dyn Objective> {
    match kind {
        LossKind::Ridge => Arc::new(Ridge::new(lam)),
        LossKind::SmoothHinge => Arc::new(SmoothHinge::new(lam)),
        LossKind::Logistic => Arc::new(Logistic::new(lam)),
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::data::Shard;
    use crate::linalg::{DataMatrix, DenseMatrix};
    use crate::util::Rng64;

    /// Random dense shard with +/-1 labels.
    pub fn class_shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Rng64::seed_from_u64(seed);
        let mut x = DenseMatrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.range_f64(-1.0, 1.0));
            }
            y.push(rng.sign());
        }
        Shard::new(DataMatrix::Dense(x), y)
    }

    /// Random dense shard with gaussian regression targets.
    pub fn reg_shard(n: usize, d: usize, seed: u64) -> Shard {
        let mut rng = Rng64::seed_from_u64(seed);
        let mut x = DenseMatrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.range_f64(-1.0, 1.0));
            }
            y.push(rng.range_f64(-2.0, 2.0));
        }
        Shard::new(DataMatrix::Dense(x), y)
    }

    /// Finite-difference gradient check: ||fd - grad||_inf.
    pub fn grad_check(
        obj: &dyn super::Objective,
        shard: &Shard,
        w: &[f64],
    ) -> f64 {
        let d = w.len();
        let n = shard.n();
        let mut rowbuf = vec![0.0; n];
        let mut g = vec![0.0; d];
        obj.value_grad(shard, w, &mut g, &mut rowbuf);
        let eps = 1e-6;
        let mut worst: f64 = 0.0;
        for j in 0..d {
            let mut wp = w.to_vec();
            let mut wm = w.to_vec();
            wp[j] += eps;
            wm[j] -= eps;
            let fp = obj.value(shard, &wp, &mut rowbuf);
            let fm = obj.value(shard, &wm, &mut rowbuf);
            let fd = (fp - fm) / (2.0 * eps);
            worst = worst.max((fd - g[j]).abs());
        }
        worst
    }
}
