//! The [`Objective`] trait and the Hessian-free operator built on it.

use crate::data::Shard;
use crate::linalg::{ops, LinearOperator};
use std::cell::RefCell;

/// A regularized shard-local empirical objective
/// `phi_i(w) = (1/n) sum_j l(...) + (lam/2)||w||^2`.
///
/// All methods take a caller-provided `rowbuf` of length `shard.n()` for
/// the per-row temporaries (margins / residuals), so the hot path never
/// allocates. Implementations must treat rows with `y == 0` *and* an
/// all-zero feature row as padding that contributes nothing — the PJRT
/// backend pads shards to the canonical artifact shape.
pub trait Objective: Send + Sync {
    /// Display name ("ridge", "smooth_hinge", ...).
    fn name(&self) -> &'static str;

    /// L2 regularization strength lambda.
    fn lambda(&self) -> f64;

    /// True when the objective is quadratic in w (fixed Hessian) — DANE
    /// then uses the cached-factorization local solver and the closed-form
    /// update of paper eq. (16).
    fn is_quadratic(&self) -> bool;

    /// phi_i(w).
    fn value(&self, shard: &Shard, w: &[f64], rowbuf: &mut [f64]) -> f64;

    /// grad phi_i(w) into `out`; returns phi_i(w) from the same pass.
    fn value_grad(
        &self,
        shard: &Shard,
        w: &[f64],
        out: &mut [f64],
        rowbuf: &mut [f64],
    ) -> f64;

    /// grad phi_i(w) into `out`.
    fn grad(&self, shard: &Shard, w: &[f64], out: &mut [f64], rowbuf: &mut [f64]) {
        self.value_grad(shard, w, out, rowbuf);
    }

    /// Per-row curvature weights `l''(r_j(w))` into `out` (length n).
    /// The shard Hessian is then `(1/n) X^T diag(out) X + lam I` — assembled
    /// only implicitly, via [`ShardHvp`].
    fn hess_weights(&self, shard: &Shard, w: &[f64], out: &mut [f64]);

    /// Smoothness constant of the *unregularized* scalar loss l (an upper
    /// bound on l''), used for GD step sizes: phi is
    /// (l_smooth * max_row_norm^2 + lam)-smooth.
    fn scalar_smoothness(&self) -> f64;
}

/// Hessian-vector-product operator of a shard objective at a fixed point:
/// `v -> (1/n) X^T diag(weights) X v + reg * v`.
///
/// `reg` is `lam + mu` for DANE local systems, `lam + rho` for ADMM prox
/// systems, plain `lam` for Newton steps on phi itself. Cost is one
/// matvec + one rmatvec per apply — O(nnz) on sparse shards, never
/// materializing a d x d Hessian (the paper's "no Hessians are explicitly
/// computed!").
pub struct ShardHvp<'a> {
    shard: &'a Shard,
    weights: &'a [f64],
    reg: f64,
    ninv: f64,
    scratch: RefCell<Vec<f64>>,
}

impl<'a> ShardHvp<'a> {
    pub fn new(shard: &'a Shard, weights: &'a [f64], reg: f64) -> Self {
        assert_eq!(weights.len(), shard.n(), "weights length");
        ShardHvp {
            shard,
            weights,
            reg,
            ninv: 1.0 / shard.n_effective() as f64,
            scratch: RefCell::new(vec![0.0; shard.n()]),
        }
    }
}

impl LinearOperator for ShardHvp<'_> {
    fn dim(&self) -> usize {
        self.shard.d()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        let mut t = self.scratch.borrow_mut();
        self.shard.x.matvec(v, &mut t).expect("hvp matvec");
        for (tj, wj) in t.iter_mut().zip(self.weights) {
            *tj *= wj * self.ninv;
        }
        self.shard.x.rmatvec(&t, out).expect("hvp rmatvec");
        ops::axpy(self.reg, v, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DataMatrix, DenseMatrix};

    #[test]
    fn hvp_matches_dense_hessian() {
        // weights = 1: HVP must equal ((1/n) X^T X + reg I) v
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, -1.0],
            vec![0.0, 1.0],
        ]);
        let shard = Shard::new(DataMatrix::Dense(x.clone()), vec![1.0, -1.0, 1.0]);
        let weights = vec![1.0; 3];
        let op = ShardHvp::new(&shard, &weights, 0.25);
        let v = vec![2.0, -3.0];
        let mut out = vec![0.0; 2];
        op.apply(&v, &mut out);

        let h = {
            let mut g = x.gram();
            for i in 0..2 {
                for j in 0..2 {
                    let val = g.get(i, j) / 3.0;
                    g.set(i, j, val);
                }
            }
            g.add_diag(0.25)
        };
        let mut expect = vec![0.0; 2];
        h.matvec(&v, &mut expect);
        for i in 0..2 {
            assert!((out[i] - expect[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn hvp_weighted_rows() {
        // zero weight on a row removes it from the Hessian entirely
        let x = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let shard = Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0]);
        let weights = vec![1.0, 0.0];
        let op = ShardHvp::new(&shard, &weights, 0.0);
        let mut out = vec![0.0; 2];
        op.apply(&[1.0, 1.0], &mut out);
        assert_eq!(out, vec![0.5, 0.0]); // 1/n = 1/2 on the surviving row
    }
}
