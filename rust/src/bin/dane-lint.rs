//! `dane-lint` — the repo's in-tree static-analysis gate.
//!
//! Usage:
//!
//! ```text
//! cargo run --bin dane-lint            # lint the enclosing repo
//! cargo run --bin dane-lint -- --root /path/to/repo
//! ```
//!
//! Walks `rust/src`, runs the five invariant rules (panic-freedom,
//! densify, wire-totality, csv-schema, determinism — see
//! `dane::analysis`), and prints one `file:line: rule: message` per
//! finding. Exit status: 0 clean, 1 violations found, 2 usage or I/O
//! error. CI runs this in the `lint` job; locally it needs no flags.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => {
                    eprintln!("dane-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: dane-lint [--root <repo-root>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("dane-lint: unknown argument {other:?} (try --help)");
                return ExitCode::from(2);
            }
        }
    }

    let root = match root.map(Ok).unwrap_or_else(find_root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("dane-lint: {e}");
            return ExitCode::from(2);
        }
    };

    match dane::analysis::lint_repo(&root) {
        Ok(diags) if diags.is_empty() => {
            println!("dane-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for d in &diags {
                println!("{d}");
            }
            println!("dane-lint: {} violation(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("dane-lint: I/O error under {}: {e}", root.display());
            ExitCode::from(2)
        }
    }
}

/// Walk upward from the current directory to the first directory that
/// contains `rust/src` (the repo root).
fn find_root() -> Result<PathBuf, String> {
    let start = std::env::current_dir().map_err(|e| e.to_string())?;
    let mut dir = start.as_path();
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Ok(dir.to_path_buf());
        }
        match dir.parent() {
            Some(p) => dir = p,
            None => {
                return Err(format!(
                    "no `rust/src` found in {} or any parent; pass --root",
                    start.display()
                ))
            }
        }
    }
}
