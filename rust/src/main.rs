//! `dane` — CLI launcher for the DANE reproduction.
//!
//! Subcommands map one-to-one onto the paper's experiments (DESIGN.md §4):
//!
//! ```text
//! dane run --config exp.json [--csv out.csv]   # any configured experiment
//! dane worker --listen addr                    # TCP worker process
//! dane quickstart                              # tiny end-to-end smoke run
//! dane fig2  [--scale K] [--out DIR]           # synthetic DANE-vs-ADMM grid
//! dane fig3  [--scale K] [--out DIR]           # iterations-to-1e-6 table
//! dane fig4  [--scale K] [--out DIR]           # test-loss curves, m = 64
//! dane thm1  [--reps N]                        # OSA lower-bound simulation
//! dane lemma2                                  # Hessian concentration sweep
//! ```
//!
//! Figure subcommands call the same harness code the benches use
//! (`dane::harness`), emitting CSV plus a printed paper-shaped table.
//! `--scale K` divides sample sizes by K for smoke runs. Argument parsing
//! is in-tree (offline build — no clap); see `Args`. Unknown subcommands
//! and unknown flags print USAGE and exit non-zero.

use dane::comm::ExecTopology;
use dane::config::{EngineKind, ExperimentConfig};
use dane::coordinator::driver::{run_experiment_with_opts, RunOpts};
use dane::harness;
use dane::metrics::emit;
use std::path::PathBuf;

const USAGE: &str = "\
dane — Communication-efficient distributed optimization (DANE, ICML 2014)

USAGE:
    dane run --config <exp.json> [--csv <out.csv>] [--quiet]
             [--engine serial|threaded|tcp] [--topology star|star-seq|tree]
             [--codec none|f32|topk:K|quant:B] [--no-ef]
             [--data-by-ref] [--checkpoint <ckpt> [--ckpt-every <K>]]
             [--resume <ckpt>]
    dane worker --listen <addr> [--once] # serve shards over TCP
    dane quickstart [--engine serial|threaded|tcp] [--topology star|star-seq|tree]
                    [--sparse]
    dane fig2   [--scale <K>] [--out <dir>] [--engine ...] [--topology ...]
    dane fig3   [--scale <K>] [--out <dir>] [--engine ...] [--topology ...]
    dane fig4   [--scale <K>] [--out <dir>] [--engine ...] [--topology ...]
    dane thm1   [--reps <N>]
    dane lemma2
    dane help

The cluster engine for `run` comes from the config (\"engine\": \"serial\"
| \"threaded\" | \"tcp\", optional \"threads\": N for the workers'
Gram-build kernel); `--engine` overrides the config value. The tcp
engine connects to the config's \"workers\" address list
(`dane worker --listen <addr>` processes), or spawns its own loopback
worker processes when the list is absent. `--topology` (config key
\"topology\") picks how the concurrent engines execute collectives:
\"star\" = parallel star (default, per-connection I/O threads),
\"star-seq\" = the leader-serialized baseline, \"tree\" = binomial
relay through the workers; traces are bit-identical across topologies,
only the modeled seconds and measured wire bytes move. `--data-by-ref`
(config key \"data\": {\"by_ref\": true}; tcp engine + libsvm dataset
only) ships each worker a reference to the dataset file instead of its
shard rows — O(m) startup bytes instead of O(n*d), with workers
streaming their own rows from local disk; traces stay bit-identical to
by-value runs. `quickstart --sparse` smoke-runs the high-dimensional
sparse path (matrix-free local solves, no dense Gram). Worker failures
and wedged workers surface as `error: ...` + non-zero exit; with
--csv the partial trace is still written, ending in a `# truncated:`
trailer. The config's \"fault\" policy (fail_fast | respawn | degrade)
decides whether a run survives a dead worker; `--checkpoint` writes
resumable state every K rounds and `--resume` continues a crashed run
bit-exactly. `--codec` (config key \"compression\": {\"codec\": ...};
concurrent engines only) compresses the round payloads on the wire —
\"f32\" downcasts, \"topk:K\" keeps the K largest-magnitude entries,
\"quant:B\" stochastically quantizes to B bits — with error feedback
on by default (`--no-ef` disables it); the trace's
`payload_bytes_raw` column records what `wire_bytes` would have been
uncompressed. `worker --listen` serves leaders in a loop (redial after
a fault re-initializes it); `--once` exits after the first session.";

/// Tiny flag parser: --key value pairs after the subcommand. Ordered
/// maps so error messages (which iterate the keys) are deterministic.
struct Args {
    flags: std::collections::BTreeMap<String, String>,
    bools: std::collections::BTreeSet<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut flags = std::collections::BTreeMap::new();
        let mut bools = std::collections::BTreeSet::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    bools.insert(key.to_string());
                    i += 1;
                }
            } else {
                return Err(format!("unexpected argument {a:?}"));
            }
        }
        Ok(Args { flags, bools })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key} must be an integer")),
        }
    }

    /// Like [`Args::get_usize`] but rejects 0: a zero scale/rep count is
    /// malformed input and must fail loudly, not be silently clamped.
    fn get_positive(&self, key: &str, default: usize) -> Result<usize, String> {
        let v = self.get_usize(key, default)?;
        if v == 0 {
            return Err(format!("--{key} must be >= 1"));
        }
        Ok(v)
    }

    /// Parse `--engine serial|threaded|tcp` (default serial).
    fn get_engine(&self) -> Result<EngineKind, String> {
        match self.get("engine") {
            None => Ok(EngineKind::Serial),
            Some(v) => EngineKind::from_name(v).map_err(|e| e.to_string()),
        }
    }

    /// Parse `--topology star|star-seq|tree` (default: parallel star).
    fn get_topology(&self) -> Result<ExecTopology, String> {
        match self.get("topology") {
            None => Ok(ExecTopology::default()),
            Some(v) => ExecTopology::from_name(v).map_err(|e| e.to_string()),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.contains(key)
    }

    /// Reject flags a subcommand does not understand, value flags missing
    /// their value, and boolean flags given one: a typo'd or malformed
    /// flag must fail loudly (USAGE + non-zero exit), not silently change
    /// the run (e.g. `fig2 --scale --out d` must not default scale to 1).
    fn check_allowed(
        &self,
        cmd: &str,
        value_flags: &[&str],
        bool_flags: &[&str],
    ) -> Result<(), String> {
        for key in self.flags.keys() {
            if bool_flags.contains(&key.as_str()) {
                return Err(format!("--{key} does not take a value"));
            }
            if !value_flags.contains(&key.as_str()) {
                return Err(format!("unknown flag --{key} for {cmd:?}"));
            }
        }
        for key in &self.bools {
            if value_flags.contains(&key.as_str()) {
                return Err(format!("--{key} requires a value"));
            }
            if !bool_flags.contains(&key.as_str()) {
                return Err(format!("unknown flag --{key} for {cmd:?}"));
            }
        }
        Ok(())
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(msg) => {
            eprintln!("error: {msg}");
            eprintln!("{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<(), String> {
    let Some(cmd) = argv.first() else {
        return Err("missing subcommand".into());
    };
    let args = Args::parse(&argv[1..])?;
    let (value_flags, bool_flags): (&[&str], &[&str]) = match cmd.as_str() {
        "run" => (
            &[
                "config",
                "csv",
                "engine",
                "topology",
                "codec",
                "checkpoint",
                "ckpt-every",
                "resume",
            ],
            &["quiet", "data-by-ref", "no-ef"],
        ),
        "worker" => (&["listen"], &["once"]),
        "fig2" | "fig3" | "fig4" => (&["scale", "out", "engine", "topology"], &[]),
        "thm1" => (&["reps"], &[]),
        "quickstart" => (&["engine", "topology"], &["sparse"]),
        "lemma2" | "help" | "--help" | "-h" => (&[], &[]),
        other => return Err(format!("unknown subcommand {other:?}")),
    };
    args.check_allowed(cmd, value_flags, bool_flags)?;
    let e2s = |e: dane::Error| e.to_string();

    match cmd.as_str() {
        "run" => {
            let config = args
                .get("config")
                .ok_or("run requires --config <exp.json>")?;
            let mut cfg = ExperimentConfig::from_json_file(&PathBuf::from(config))
                .map_err(e2s)?;
            // The config's engine/topology win unless the flags are
            // passed.
            if let Some(engine) = args.get("engine") {
                cfg.engine = EngineKind::from_name(engine).map_err(e2s)?;
            }
            if let Some(topology) = args.get("topology") {
                cfg.topology = Some(ExecTopology::from_name(topology).map_err(e2s)?);
            }
            if args.has("data-by-ref") {
                cfg.data_by_ref = true;
            }
            if let Some(codec) = args.get("codec") {
                cfg.compression.codec =
                    dane::config::CompressionCodec::from_cli(codec).map_err(e2s)?;
            }
            if args.has("no-ef") {
                cfg.compression.error_feedback = false;
            }
            let opts = RunOpts {
                checkpoint: args.get("checkpoint").map(PathBuf::from),
                ckpt_every: args.get_positive("ckpt-every", 1)?,
                resume: args.get("resume").map(PathBuf::from),
            };
            let res = match run_experiment_with_opts(&cfg, &opts) {
                Ok(res) => res,
                // A failed run still writes what it recorded: the partial
                // trace lands in --csv with a `# truncated: <cause>`
                // trailer before the error propagates.
                Err(dane::Error::Algo(ae)) => {
                    if let Some(path) = args.get("csv") {
                        emit::write_csv_file_truncated(
                            &ae.trace,
                            &PathBuf::from(path),
                            &ae.error.to_string(),
                        )
                        .map_err(e2s)?;
                        eprintln!("wrote partial trace to {path}");
                    }
                    return Err(ae.to_string());
                }
                Err(e) => return Err(e2s(e)),
            };
            if let Some(path) = args.get("csv") {
                emit::write_csv_file(&res.trace, &PathBuf::from(path)).map_err(e2s)?;
                println!("wrote {path}");
            }
            if !args.has("quiet") {
                print_trace_tail(&res.trace, 12);
            }
            println!("{}", emit::summary_json(&cfg.name, &res.trace).to_string_pretty());
            if let Some(r) = res.rounds_to_tol {
                println!("rounds to {:.0e}: {r}", cfg.tol);
            }
            Ok(())
        }
        "worker" => {
            let addr = args
                .get("listen")
                .ok_or("worker requires --listen <addr>")?;
            dane::worker::serve::serve_addr(addr, args.has("once")).map_err(e2s)
        }
        "quickstart" => {
            if args.has("sparse") {
                harness::quickstart_sparse(args.get_engine()?, args.get_topology()?)
                    .map_err(e2s)
            } else {
                harness::quickstart(args.get_engine()?, args.get_topology()?).map_err(e2s)
            }
        }
        "fig2" => {
            let scale = args.get_positive("scale", 1)?;
            let out = PathBuf::from(args.get("out").unwrap_or("results/fig2"));
            harness::fig2(scale, &out, args.get_engine()?, args.get_topology()?)
                .map(|_| ())
                .map_err(e2s)
        }
        "fig3" => {
            let scale = args.get_positive("scale", 1)?;
            let out = PathBuf::from(args.get("out").unwrap_or("results/fig3"));
            harness::fig3(scale, &out, args.get_engine()?, args.get_topology()?)
                .map(|_| ())
                .map_err(e2s)
        }
        "fig4" => {
            let scale = args.get_positive("scale", 1)?;
            let out = PathBuf::from(args.get("out").unwrap_or("results/fig4"));
            harness::fig4(scale, &out, args.get_engine()?, args.get_topology()?)
                .map(|_| ())
                .map_err(e2s)
        }
        "thm1" => {
            let reps = args.get_positive("reps", 200)?;
            harness::thm1(reps).map(|_| ()).map_err(e2s)
        }
        "lemma2" => harness::lemma2().map(|_| ()).map_err(e2s),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}")),
    }
}

fn print_trace_tail(trace: &dane::metrics::Trace, k: usize) {
    println!(
        "{:>6} {:>14} {:>14} {:>12} {:>8}",
        "round", "objective", "subopt", "gradnorm", "comm"
    );
    let skip = trace.rows.len().saturating_sub(k);
    for r in trace.rows.iter().skip(skip) {
        println!(
            "{:>6} {:>14.6e} {:>14} {:>12} {:>8}",
            r.round,
            r.objective,
            r.suboptimality.map(|s| format!("{s:.3e}")).unwrap_or_default(),
            r.grad_norm.map(|g| format!("{g:.3e}")).unwrap_or_default(),
            r.comm_rounds,
        );
    }
}
