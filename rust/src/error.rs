//! Crate-wide error type.

/// Unified error for all dane subsystems.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Dimension mismatch between operands.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// A numerical routine failed (non-SPD matrix, CG breakdown, ...).
    #[error("numerical failure: {0}")]
    Numerical(String),

    /// Bad or inconsistent configuration / parse failure.
    #[error("config error: {0}")]
    Config(String),

    /// Artifact manifest / PJRT runtime problems.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// An algorithm failed to converge within its round budget.
    #[error("did not converge: {0}")]
    NoConvergence(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// Errors bubbled up from the xla/PJRT bridge.
    #[error("xla error: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("3 vs 4".into());
        assert!(e.to_string().contains("3 vs 4"));
        let e = Error::Config("bad key".into());
        assert!(e.to_string().contains("config"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }
}
