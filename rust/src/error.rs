//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline build has no
//! `thiserror`); the message prefixes are part of the public contract —
//! tests and the CLI grep for them.

use std::fmt;

/// Unified error for all dane subsystems.
#[derive(Debug)]
pub enum Error {
    /// Dimension mismatch between operands.
    Shape(String),

    /// A numerical routine failed (non-SPD matrix, CG breakdown, ...).
    Numerical(String),

    /// Bad or inconsistent configuration / parse failure.
    Config(String),

    /// Artifact manifest / PJRT runtime problems.
    Runtime(String),

    /// An algorithm failed to converge within its round budget.
    NoConvergence(String),

    Io(std::io::Error),

    /// Errors bubbled up from the xla/PJRT bridge.
    Xla(String),

    /// A worker became unreachable mid-run: dead socket, wedged link,
    /// disconnected channel. The one *recoverable* failure class — a
    /// `FaultPolicy` supervisor may respawn the worker or degrade the
    /// quorum and retry, where every other variant (including a
    /// worker-side compute failure reported over a healthy link) stays
    /// fatal under every policy.
    WorkerLost(String),

    /// An algorithm run failed, carrying the iterate and trace recorded
    /// before the failure so callers can emit partial artifacts.
    Algo(Box<crate::coordinator::AlgoError>),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Numerical(s) => write!(f, "numerical failure: {s}"),
            Error::Config(s) => write!(f, "config error: {s}"),
            Error::Runtime(s) => write!(f, "runtime error: {s}"),
            Error::NoConvergence(s) => write!(f, "did not converge: {s}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Xla(s) => write!(f, "xla error: {s}"),
            Error::WorkerLost(s) => write!(f, "worker lost: {s}"),
            // Renders exactly as the old stringly flattening did
            // ("runtime error: <algo> failed after ..."), so the CLI's
            // error output is byte-identical.
            Error::Algo(e) => write!(f, "runtime error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Algo(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::xla::Error> for Error {
    fn from(e: crate::xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Shape("3 vs 4".into());
        assert!(e.to_string().contains("3 vs 4"));
        let e = Error::Config("bad key".into());
        assert!(e.to_string().contains("config"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
    }

    #[test]
    fn worker_lost_displays_the_link() {
        let e = Error::WorkerLost("tcp: worker 2: wedged".into());
        assert!(e.to_string().contains("worker lost"));
        assert!(e.to_string().contains("worker 2"));
    }

    #[test]
    fn xla_error_converts() {
        let e: Error = crate::xla::Error("no pjrt".into()).into();
        assert!(matches!(e, Error::Xla(_)));
        assert!(e.to_string().contains("no pjrt"));
    }
}
