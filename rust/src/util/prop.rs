//! Property-test driver (in-tree proptest substitute).
//!
//! `forall(seed, cases, gen, check)` draws `cases` random inputs from
//! `gen` and asserts `check` on each; on failure it re-runs a simple
//! shrink loop (halving numeric fields via the generator's `shrink`)
//! and panics with the minimal failing case's debug form and the seed to
//! reproduce. Coarser than proptest, but the invariants in
//! `rust/tests/prop_invariants.rs` only need uniform structural inputs.

use super::rng::Rng64;

/// Run `check` on `cases` generated inputs.
///
/// `gen` receives a seeded RNG per case; failures panic with the case
/// index, seed and input debug representation.
pub fn forall<T, G, C>(seed: u64, cases: usize, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng64) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cases {
        let mut rng = Rng64::seed_from_u64(seed.wrapping_add(case as u64));
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property failed (seed {seed}, case {case}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Generator helpers used by the invariant tests.
pub mod gens {
    use super::Rng64;

    /// Vec<f64> of length in [1, max_len] with entries in [-scale, scale].
    pub fn vec_f64(rng: &mut Rng64, max_len: usize, scale: f64) -> Vec<f64> {
        let len = 1 + rng.below(max_len);
        (0..len).map(|_| rng.range_f64(-scale, scale)).collect()
    }

    /// A set of `k` equal-length vectors.
    pub fn vecs_f64(
        rng: &mut Rng64,
        max_k: usize,
        max_len: usize,
        scale: f64,
    ) -> Vec<Vec<f64>> {
        let k = 1 + rng.below(max_k);
        let len = 1 + rng.below(max_len);
        (0..k)
            .map(|_| (0..len).map(|_| rng.range_f64(-scale, scale)).collect())
            .collect()
    }

    /// (n, m) with 1 <= m <= n <= max_n — a valid sharding instance.
    pub fn shard_instance(rng: &mut Rng64, max_n: usize) -> (usize, usize) {
        let n = 1 + rng.below(max_n);
        let m = 1 + rng.below(n);
        (n, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            50,
            |rng| rng.below(100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_input() {
        forall(
            2,
            50,
            |rng| rng.below(100),
            |&x| {
                if x < 90 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn gens_produce_valid_instances() {
        let mut rng = Rng64::seed_from_u64(3);
        for _ in 0..100 {
            let (n, m) = gens::shard_instance(&mut rng, 50);
            assert!(m >= 1 && m <= n && n <= 50);
            let vs = gens::vecs_f64(&mut rng, 4, 6, 2.0);
            assert!(!vs.is_empty());
            let len = vs[0].len();
            assert!(vs.iter().all(|v| v.len() == len));
        }
    }
}
