//! In-tree substrates for an offline build.
//!
//! The build environment vendors only the xla bridge and a handful of
//! leaf crates, so the usual ecosystem pieces are implemented here from
//! scratch (DESIGN.md §5): a seeded PRNG with the distributions the data
//! generators need, a JSON parser/serializer for configs + the artifact
//! manifest, a micro-benchmark harness with criterion-style reporting,
//! and a property-test driver.

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod tempdir;

pub use json::Json;
pub use rng::Rng64;
