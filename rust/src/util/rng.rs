//! Seeded pseudo-random numbers: splitmix64 core + the distributions the
//! data generators and solvers need (uniform, normal, Bernoulli,
//! Fisher-Yates shuffle, weighted choice).
//!
//! Determinism contract: identical seeds produce identical streams on
//! every platform (pure integer arithmetic, explicit IEEE conversions).
//! Every experiment in EXPERIMENTS.md records its seed; the statistical
//! quality of splitmix64 is far beyond what sampling Gaussian features
//! requires (it passes BigCrush when used as a 64-bit stream).

/// Splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng64 {
    state: u64,
    /// Cached second Box-Muller deviate.
    spare_normal: Option<f64>,
}

impl Rng64 {
    pub fn seed_from_u64(seed: u64) -> Self {
        Rng64 { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply method (Lemire, unbiased enough for data gen;
        // the modulo bias at these n is < 2^-53).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (second deviate cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u in (0,1] to keep ln finite
        let u = 1.0 - self.f64();
        let v = self.f64();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// N(mu, sigma^2).
    pub fn normal_with(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Index drawn proportionally to the (nonnegative) cumulative weights
    /// `cum` (nondecreasing, last element = total mass).
    pub fn weighted_index(&mut self, cum: &[f64]) -> usize {
        let total = *cum.last().expect("empty weights");
        let u = self.range_f64(0.0, total);
        cum.partition_point(|&c| c < u).min(cum.len() - 1)
    }

    /// +1.0 or -1.0 with equal probability.
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_under_seed() {
        let mut a = Rng64::seed_from_u64(7);
        let mut b = Rng64::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng64::seed_from_u64(8);
        assert_ne!(Rng64::seed_from_u64(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng64::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_and_variance() {
        let mut r = Rng64::seed_from_u64(2);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "{var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let kurt = xs.iter().map(|x| x.powi(4)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.02, "{var}");
        assert!((kurt - 3.0).abs() < 0.1, "{kurt}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng64::seed_from_u64(4);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng64::seed_from_u64(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng64::seed_from_u64(6);
        let cum = vec![1.0, 1.0, 11.0]; // weights 1, 0, 10
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_index(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 8 * counts[0], "{counts:?}");
    }

    #[test]
    fn bernoulli_rate() {
        let mut r = Rng64::seed_from_u64(9);
        let hits = (0..100_000).filter(|_| r.bool(0.3)).count();
        assert!((hits as f64 / 1e5 - 0.3).abs() < 0.01);
    }
}
