//! Minimal JSON: recursive-descent parser + serializer.
//!
//! Serves the artifact manifest (`artifacts/manifest.json`), experiment
//! configs and metric summaries. Full RFC-8259 value grammar with the
//! usual escapes; numbers parse as f64 (ints up to 2^53 round-trip, which
//! covers every count this crate emits).

use crate::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-stable iteration (BTreeMap: sorted keys —
    /// deterministic output, which the golden tests rely on).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------------ parse

    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but an error mentioning the key when missing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Config(format!("missing JSON key {key:?}")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // ------------------------------------------------------- construct

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    // ------------------------------------------------------- serialize

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, what: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {what}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ] in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or } in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not emitted by
                            // any producer in this repo); reject cleanly.
                            let c = char::from_u32(cp)
                                .ok_or_else(|| self.err("surrogate \\u escape"))?;
                            out.push(c);
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":[{"file":"x.hlo.txt","n_outputs":2,"static":{"d":64,"n":256}}],"format":"hlo-text","return_tuple":true}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
        // pretty form reparses to the same value
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"", "{\"a\" 1}", "nul", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""é\t\\""#).unwrap();
        assert_eq!(v.as_str(), Some("é\t\\"));
        assert_eq!(Json::Str("a\"b".into()).to_string(), r#""a\"b""#);
    }

    #[test]
    fn numeric_accessors() {
        let v = Json::parse("[3, 3.5, -1]").unwrap();
        let a = v.as_array().unwrap();
        assert_eq!(a[0].as_usize(), Some(3));
        assert_eq!(a[1].as_usize(), None);
        assert_eq!(a[2].as_usize(), None);
        assert_eq!(a[1].as_f64(), Some(3.5));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn req_reports_key() {
        let v = Json::parse("{}").unwrap();
        let err = v.req("name").unwrap_err().to_string();
        assert!(err.contains("name"), "{err}");
    }
}
