//! Micro-benchmark harness (criterion-style, in-tree).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::bench`] for timing-sensitive measurements and print the
//! paper-shaped tables for the figure harnesses. Reporting: median +
//! interquartile range over sample batches, with warmup — the same
//! methodology criterion uses, minus the statistical machinery an
//! offline build can't pull in.
//!
//! Every `bench` call is also recorded, and [`Bencher::write_json`]
//! serializes the run to a machine-readable trajectory file
//! (`BENCH_hotpath.json` at the repo root for the hot-path suite), so
//! perf claims in PRs are checkable against committed numbers
//! (EXPERIMENTS.md §Perf). Durations honor the `BENCH_MEASURE_MS` /
//! `BENCH_WARMUP_MS` environment variables via [`Bencher::from_env`] —
//! CI's bench-smoke job shrinks them to seconds-total.

use crate::util::json::Json;
use std::cell::RefCell;
use std::path::Path;
use std::time::{Duration, Instant};

/// One benchmark runner with shared settings.
pub struct Bencher {
    /// Target time to spend measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
    /// Max samples (batches) collected.
    pub max_samples: usize,
    /// Every completed measurement, in call order (JSON sink).
    records: RefCell<Vec<BenchRecord>>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new(Duration::from_secs(2), Duration::from_millis(300), 60)
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub median: Duration,
    pub p25: Duration,
    pub p75: Duration,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchStats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_sample as f64
    }
}

/// One recorded measurement (per-iteration nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    pub name: String,
    pub median_ns: f64,
    pub p25_ns: f64,
    pub p75_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl Bencher {
    pub fn new(measure_time: Duration, warmup_time: Duration, max_samples: usize) -> Self {
        Bencher { measure_time, warmup_time, max_samples, records: RefCell::new(Vec::new()) }
    }

    /// [`Bencher::new`] with durations overridable from the environment
    /// (`BENCH_MEASURE_MS`, `BENCH_WARMUP_MS`) so CI can smoke the bench
    /// binaries in seconds while local runs keep meaningful sample sizes.
    pub fn from_env(default_measure_ms: u64, default_warmup_ms: u64, max_samples: usize) -> Self {
        let env_ms = |key: &str, default: u64| {
            std::env::var(key)
                .ok()
                .and_then(|v| v.parse::<u64>().ok())
                .unwrap_or(default)
        };
        Bencher::new(
            Duration::from_millis(env_ms("BENCH_MEASURE_MS", default_measure_ms)),
            Duration::from_millis(env_ms("BENCH_WARMUP_MS", default_warmup_ms)),
            max_samples,
        )
    }

    /// Time `f`, batching iterations so each sample lasts >= ~1ms, and
    /// print a criterion-style line. Returns the stats for programmatic
    /// use and records them for [`Bencher::write_json`].
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup + batch-size calibration.
        let mut iters: u64 = 1;
        let warmup_end = Instant::now() + self.warmup_time;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if Instant::now() >= warmup_end && dt >= Duration::from_micros(500) {
                // aim for ~2ms per sample
                let scale = (2_000_000.0 / dt.as_nanos().max(1) as f64
                    * iters as f64)
                    .clamp(1.0, 1e9);
                iters = scale as u64;
                break;
            }
            if dt < Duration::from_micros(500) {
                iters = iters.saturating_mul(2);
            }
        }

        // Measurement.
        let mut samples: Vec<Duration> = Vec::new();
        let end = Instant::now() + self.measure_time;
        while Instant::now() < end && samples.len() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let stats = BenchStats {
            median: samples[samples.len() / 2],
            p25: samples[samples.len() / 4],
            p75: samples[samples.len() * 3 / 4],
            iters_per_sample: iters,
            samples: samples.len(),
        };
        println!(
            "bench {name:<44} {:>12}/iter  [{} .. {}]  ({} samples x {} iters)",
            fmt_ns(stats.per_iter_ns()),
            fmt_ns(stats.p25.as_nanos() as f64 / iters as f64),
            fmt_ns(stats.p75.as_nanos() as f64 / iters as f64),
            stats.samples,
            stats.iters_per_sample,
        );
        self.records.borrow_mut().push(BenchRecord {
            name: name.to_string(),
            median_ns: stats.per_iter_ns(),
            p25_ns: stats.p25.as_nanos() as f64 / iters as f64,
            p75_ns: stats.p75.as_nanos() as f64 / iters as f64,
            iters_per_sample: stats.iters_per_sample,
            samples: stats.samples,
        });
        stats
    }

    /// Record a non-timing measurement (an allocation count, a byte
    /// total) under `name` so it rides in the JSON trajectory next to
    /// the timings. The value lands in the `median_ns` column —
    /// `dane-bench-v1` has one value column and the entry name carries
    /// the unit — with p25/p75 repeating it and iters/samples set to 1.
    pub fn record_value(&self, name: &str, value: f64) {
        println!("value {name:<44} {value}");
        self.records.borrow_mut().push(BenchRecord {
            name: name.to_string(),
            median_ns: value,
            p25_ns: value,
            p75_ns: value,
            iters_per_sample: 1,
            samples: 1,
        });
    }

    /// Recorded measurements so far, in call order.
    pub fn records(&self) -> Vec<BenchRecord> {
        self.records.borrow().clone()
    }

    /// Median ns of the record whose name matches exactly.
    pub fn median_ns_of(&self, name: &str) -> Option<f64> {
        self.records
            .borrow()
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median_ns)
    }

    /// Serialize every recorded measurement to `path` as pretty JSON:
    /// `{schema, bench, label, results: [{name, median_ns, p25_ns,
    /// p75_ns, iters_per_sample, samples}, ...]}`. The label should make
    /// the run git-describable (see [`git_label`]).
    pub fn write_json(&self, path: &Path, bench_name: &str, label: &str) -> std::io::Result<()> {
        let results: Vec<Json> = self
            .records
            .borrow()
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("median_ns", Json::num(r.median_ns)),
                    ("p25_ns", Json::num(r.p25_ns)),
                    ("p75_ns", Json::num(r.p75_ns)),
                    ("iters_per_sample", Json::num(r.iters_per_sample as f64)),
                    ("samples", Json::num(r.samples as f64)),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("schema", Json::str("dane-bench-v1")),
            ("bench", Json::str(bench_name)),
            ("label", Json::str(label)),
            ("results", Json::Arr(results)),
        ]);
        std::fs::write(path, doc.to_string_pretty() + "\n")
    }
}

/// A git-describable label for bench trajectories: `BENCH_LABEL` env var
/// if set, else `git describe --always --dirty`, else "unknown".
pub fn git_label() -> String {
    if let Ok(l) = std::env::var("BENCH_LABEL") {
        return l;
    }
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Human duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Bencher {
        Bencher::new(Duration::from_millis(50), Duration::from_millis(5), 10)
    }

    #[test]
    fn bench_produces_sane_stats() {
        let b = quick();
        let mut acc = 0u64;
        let stats = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(stats.samples >= 1);
        assert!(stats.per_iter_ns() >= 0.0);
        assert!(stats.p25 <= stats.p75);
    }

    #[test]
    fn records_and_json_roundtrip() {
        let b = quick();
        let mut acc = 0u64;
        b.bench("first", || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.bench("second", || {
            acc = black_box(acc.wrapping_add(3));
        });
        assert_eq!(b.records().len(), 2);
        assert!(b.median_ns_of("first").is_some());
        assert!(b.median_ns_of("missing").is_none());

        let dir = crate::util::tempdir::TempDir::new("bench_json").unwrap();
        let path = dir.path().join("out.json");
        b.write_json(&path, "unit_test", "test-label").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.req("schema").unwrap().as_str(), Some("dane-bench-v1"));
        assert_eq!(doc.req("bench").unwrap().as_str(), Some("unit_test"));
        assert_eq!(doc.req("label").unwrap().as_str(), Some("test-label"));
        let results = doc.req("results").unwrap().as_array().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].req("name").unwrap().as_str(), Some("first"));
        assert!(results[0].req("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(results[1].req("samples").unwrap().as_usize().unwrap() >= 1);
    }

    #[test]
    fn record_value_lands_in_records_and_json() {
        let b = quick();
        b.record_value("leader allocs/round m=4 star", 0.0);
        let recs = b.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].median_ns, 0.0);
        assert_eq!(recs[0].samples, 1);
        assert_eq!(b.median_ns_of("leader allocs/round m=4 star"), Some(0.0));
    }

    #[test]
    fn from_env_falls_back_to_defaults() {
        // (environment not set in tests; just pin the default wiring)
        let b = Bencher::from_env(123, 7, 5);
        if std::env::var("BENCH_MEASURE_MS").is_err() {
            assert_eq!(b.measure_time, Duration::from_millis(123));
        }
        if std::env::var("BENCH_WARMUP_MS").is_err() {
            assert_eq!(b.warmup_time, Duration::from_millis(7));
        }
        assert_eq!(b.max_samples, 5);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("us"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
