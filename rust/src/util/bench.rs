//! Micro-benchmark harness (criterion-style, in-tree).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bencher::bench`] for timing-sensitive measurements and print the
//! paper-shaped tables for the figure harnesses. Reporting: median +
//! interquartile range over sample batches, with warmup — the same
//! methodology criterion uses, minus the statistical machinery an
//! offline build can't pull in.

use std::time::{Duration, Instant};

/// One benchmark runner with shared settings.
pub struct Bencher {
    /// Target time to spend measuring each benchmark.
    pub measure_time: Duration,
    /// Warmup time before measurement.
    pub warmup_time: Duration,
    /// Max samples (batches) collected.
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            max_samples: 60,
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub median: Duration,
    pub p25: Duration,
    pub p75: Duration,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchStats {
    pub fn per_iter_ns(&self) -> f64 {
        self.median.as_nanos() as f64 / self.iters_per_sample as f64
    }
}

impl Bencher {
    /// Time `f`, batching iterations so each sample lasts >= ~1ms, and
    /// print a criterion-style line. Returns the stats for programmatic
    /// use (EXPERIMENTS.md tables).
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        // Warmup + batch-size calibration.
        let mut iters: u64 = 1;
        let warmup_end = Instant::now() + self.warmup_time;
        loop {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            let dt = t0.elapsed();
            if Instant::now() >= warmup_end && dt >= Duration::from_micros(500) {
                // aim for ~2ms per sample
                let scale = (2_000_000.0 / dt.as_nanos().max(1) as f64
                    * iters as f64)
                    .clamp(1.0, 1e9);
                iters = scale as u64;
                break;
            }
            if dt < Duration::from_micros(500) {
                iters = iters.saturating_mul(2);
            }
        }

        // Measurement.
        let mut samples: Vec<Duration> = Vec::new();
        let end = Instant::now() + self.measure_time;
        while Instant::now() < end && samples.len() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed());
        }
        if samples.is_empty() {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let stats = BenchStats {
            median: samples[samples.len() / 2],
            p25: samples[samples.len() / 4],
            p75: samples[samples.len() * 3 / 4],
            iters_per_sample: iters,
            samples: samples.len(),
        };
        println!(
            "bench {name:<44} {:>12}/iter  [{} .. {}]  ({} samples x {} iters)",
            fmt_ns(stats.per_iter_ns()),
            fmt_ns(stats.p25.as_nanos() as f64 / iters as f64),
            fmt_ns(stats.p75.as_nanos() as f64 / iters as f64),
            stats.samples,
            stats.iters_per_sample,
        );
        stats
    }
}

/// Human duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let b = Bencher {
            measure_time: Duration::from_millis(50),
            warmup_time: Duration::from_millis(5),
            max_samples: 10,
        };
        let mut acc = 0u64;
        let stats = b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(stats.samples >= 1);
        assert!(stats.per_iter_ns() >= 0.0);
        assert!(stats.p25 <= stats.p75);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("us"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
