//! Vector kernels used by every hot loop in the crate.
//!
//! Written as straight slice loops so LLVM autovectorizes them; the
//! criterion bench `hotpath_micro` pins their throughput. Panics on length
//! mismatch (debug_assert in release-hot paths) — these are internal
//! primitives, shape checking happens at the module boundaries.
//!
//! ## Canonical lane fold
//!
//! Every reduction kernel here and in [`super::sparse`] (`dot`, `dist2`,
//! `row_dot`, `row_sq_norm`) uses the same fixed 4-lane multi-accumulator
//! shape: lanes `a0..a3` stride the input by 4, combine as
//! `(a0 + a2) + (a1 + a3)`, and a strictly sequential remainder loop
//! finishes the tail. The lane structure is part of the numeric contract,
//! not just a speed trick — it is identical for every thread count and
//! engine, so results stay bit-reproducible across the whole
//! serial ≡ threaded ≡ tcp parity matrix (`tests/kernel_parity.rs` pins
//! the fold order against naive 4-lane references, and the `dane-lint`
//! determinism rule flags any kernel on its allowlist that loses the
//! `a0..a3` lanes). Element-wise kernels (`axpy`, `axpby`, `scale`, ...)
//! have no reduction and need no lanes; `axpy_panel` stays strictly
//! sequential by design (the padded-shard bit-exactness invariant).

/// dot(x, y) = sum_i x_i y_i
///
/// Four independent accumulators: a strict sequential FP reduction cannot
/// be vectorized by LLVM (reassociation changes the result), so the naive
/// loop runs at ~1 madd per 2 cycles. Splitting the reduction into four
/// lanes re-enables SIMD + ILP — measured 3-4x on the d=512 hot path
/// (EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = 4 * i;
        a0 += x[j] * y[j];
        a1 += x[j + 1] * y[j + 1];
        a2 += x[j + 2] * y[j + 2];
        a3 += x[j + 3] * y[j + 3];
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for i in 4 * chunks..n {
        acc += x[i] * y[i];
    }
    acc
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// out[i] += sum_k coeffs[k] * rows[k][i] — the register-blocked panel
/// microkernel of the tiled Gram assembly (`DenseMatrix::gram`).
///
/// `K` is a compile-time constant so the inner sum fully unrolls into K
/// independent fused multiply-adds per output element; the K row slices
/// stream from L1 while the single `out` row is read and written once.
/// The accumulation is strictly sequential (out, then coeff 0, 1, ... in
/// order), which makes the result independent of how a row range is
/// decomposed into panels: appending all-zero rows adds exact `+0.0`
/// terms and leaves every partial sum bit-identical — the padded-shard
/// invariant the QuadCache tests pin.
#[inline]
pub fn axpy_panel<const K: usize>(coeffs: &[f64; K], rows: &[&[f64]; K], out: &mut [f64]) {
    let n = out.len();
    for k in 0..K {
        debug_assert!(rows[k].len() >= n);
    }
    for i in 0..n {
        let mut s = out[i];
        for k in 0..K {
            s += coeffs[k] * rows[k][i];
        }
        out[i] = s;
    }
}

/// y = alpha * x + beta * y
#[inline]
pub fn axpby(alpha: f64, x: &[f64], beta: f64, y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] = alpha * x[i] + beta * y[i];
    }
}

/// x *= alpha
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm ||x||.
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// ||x - y||
///
/// Same canonical 4-lane fold as [`dot`] (module docs): four
/// independent accumulators let LLVM vectorize the squared-difference
/// reduction, and the fixed `(a0 + a2) + (a1 + a3)` combine keeps the
/// result bit-reproducible everywhere the convergence loop's
/// step-distance check runs.
#[inline]
pub fn dist2(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 4;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..chunks {
        let j = 4 * i;
        let (d0, d1, d2, d3) = (
            x[j] - y[j],
            x[j + 1] - y[j + 1],
            x[j + 2] - y[j + 2],
            x[j + 3] - y[j + 3],
        );
        a0 += d0 * d0;
        a1 += d1 * d1;
        a2 += d2 * d2;
        a3 += d3 * d3;
    }
    let mut acc = (a0 + a2) + (a1 + a3);
    for i in 4 * chunks..n {
        let d = x[i] - y[i];
        acc += d * d;
    }
    acc.sqrt()
}

/// out = x - y
#[inline]
pub fn sub(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] - y[i];
    }
}

/// out = x + y
#[inline]
pub fn add(x: &[f64], y: &[f64], out: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for i in 0..x.len() {
        out[i] = x[i] + y[i];
    }
}

/// Copy src into dst.
#[inline]
pub fn copy(src: &[f64], dst: &mut [f64]) {
    dst.copy_from_slice(src);
}

/// Mean of a set of equal-length vectors into `out`. The serial reduction
/// the collective layer's allreduce must agree with (see comm tests).
pub fn mean_into(vecs: &[&[f64]], out: &mut [f64]) {
    assert!(!vecs.is_empty());
    out.fill(0.0);
    for v in vecs {
        axpy(1.0, v, out);
    }
    scale(1.0 / vecs.len() as f64, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_basic() {
        assert_eq!(dot(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]), 32.0);
        assert_eq!(dot(&[], &[]), 0.0);
    }

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[3.0, -1.0], &mut y);
        assert_eq!(y, vec![7.0, -1.0]);
    }

    #[test]
    fn axpby_basic() {
        let mut y = vec![1.0, 2.0];
        axpby(2.0, &[1.0, 1.0], -1.0, &mut y);
        assert_eq!(y, vec![1.0, 0.0]);
    }

    #[test]
    fn norms() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(dist2(&[1.0, 1.0], &[4.0, 5.0]), 5.0);
    }

    #[test]
    fn dist2_matches_canonical_lane_fold() {
        // the canonical fold order is part of the contract (module
        // docs): lanes stride by 4, combine (a0+a2)+(a1+a3), then a
        // sequential remainder — pin it bit-for-bit on an odd length
        let x: Vec<f64> = (0..11).map(|i| 0.1 * i as f64 - 0.3).collect();
        let y: Vec<f64> = (0..11).map(|i| 0.07 * (i * i) as f64).collect();
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
        for j in (0..8).step_by(4) {
            let d = |k: usize| x[j + k] - y[j + k];
            a0 += d(0) * d(0);
            a1 += d(1) * d(1);
            a2 += d(2) * d(2);
            a3 += d(3) * d(3);
        }
        let mut acc = (a0 + a2) + (a1 + a3);
        for i in 8..11 {
            let d = x[i] - y[i];
            acc += d * d;
        }
        assert_eq!(dist2(&x, &y).to_bits(), acc.sqrt().to_bits());
    }

    #[test]
    fn axpy_panel_matches_sequential_axpys() {
        let r0 = vec![1.0, 2.0, 3.0];
        let r1 = vec![-1.0, 0.5, 2.0];
        let r2 = vec![0.0, 4.0, -2.0];
        let mut out = vec![10.0, 20.0, 30.0];
        axpy_panel(&[2.0, -1.0, 0.5], &[&r0, &r1, &r2], &mut out);
        assert_eq!(out, vec![10.0 + 2.0 + 1.0, 20.0 + 4.0 - 0.5 + 2.0, 30.0 + 6.0 - 2.0 - 1.0]);
    }

    #[test]
    fn axpy_panel_zero_coeff_rows_are_exact_noops() {
        // appending zero rows to a panel must not perturb bits
        let r0 = vec![0.125, -3.5];
        let z = vec![0.0, 0.0];
        let mut a = vec![1.0, 2.0];
        let mut b = vec![1.0, 2.0];
        axpy_panel(&[0.25], &[&r0], &mut a);
        axpy_panel(&[0.25, 0.0, 0.0], &[&r0, &z, &z], &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn mean_matches_serial() {
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 6.0];
        let mut out = vec![0.0; 2];
        mean_into(&[&a, &b], &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }
}
