//! Dense row-major matrix.
//!
//! Row-major because every access pattern in the stack is row-streamed:
//! matvec walks rows, rmatvec accumulates row-scaled contributions, the
//! Gram product is a rank-1 accumulation per row. This matches the L1
//! Pallas kernel, which streams (block_rows, d) tiles of X through VMEM.

use super::ops;

/// Rows per register-blocked Gram panel (microkernel height). 8 rows x
/// one g-row keeps 9 block-chunks live, comfortably inside L1 with
/// [`GRAM_COL_BLOCK`]-sized chunks.
const GRAM_PANEL_ROWS: usize = 8;

/// Features per Gram column block: 128 f64 = 1 KiB per row chunk, so a
/// full 8-row panel's working set is 8 KiB + the streamed g-row.
const GRAM_COL_BLOCK: usize = 128;

/// Copy the strictly-upper triangle onto the strictly-lower one.
fn mirror_upper_to_lower(g: &mut DenseMatrix) {
    let d = g.cols;
    for a in 0..d {
        for b in (a + 1)..d {
            let v = g.get(a, b);
            g.set(b, a, v);
        }
    }
}

/// Dense n x d matrix, row-major contiguous storage.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From a contiguous row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        DenseMatrix { rows, cols, data }
    }

    /// From explicit row vectors (tests & tiny fixtures).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { rows: r, cols: c, data }
    }

    /// d x d identity.
    pub fn eye(d: usize) -> Self {
        let mut m = Self::zeros(d, d);
        for i in 0..d {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// out = A v  (row-streamed; one dot per row).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            out[i] = ops::dot(self.row(i), v);
        }
    }

    /// out = A^T u  (row-streamed accumulation).
    pub fn rmatvec(&self, u: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        self.rmatvec_acc(u, out);
    }

    /// out += A^T u
    pub fn rmatvec_acc(&self, u: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let ui = u[i];
            if ui != 0.0 {
                ops::axpy(ui, self.row(i), out);
            }
        }
    }

    /// Gram matrix A^T A (d x d) via the tiled kernel: row panels of
    /// [`GRAM_PANEL_ROWS`] data rows x column blocks of
    /// [`GRAM_COL_BLOCK`] features, with the register-blocked
    /// [`ops::axpy_panel`] microkernel doing the per-(panel, block)
    /// update. Compared with the previous 2-row scheme (kept as
    /// [`DenseMatrix::gram_2row`] for benches and parity tests) the
    /// dominant g-row traffic drops by panel_rows/2 = 4x, and the
    /// panel's column-block chunks stay L1-resident across the whole
    /// feature loop (EXPERIMENTS.md §Perf). Upper triangle is computed,
    /// then mirrored.
    pub fn gram(&self) -> DenseMatrix {
        let d = self.cols;
        let mut g = DenseMatrix::zeros(d, d);
        self.gram_acc_rows(0, self.rows, &mut g);
        mirror_upper_to_lower(&mut g);
        g
    }

    /// Deterministic multi-threaded Gram: rows are split into `threads`
    /// fixed contiguous chunks, each chunk's partial Gram is computed
    /// with the same tiled kernel on its own thread
    /// (`std::thread::scope`), and the partials are reduced in chunk
    /// order. For a given (shape, threads) the chunking, the per-chunk
    /// kernel and the reduction order are all fixed, so the result is
    /// bit-reproducible across runs; `par_gram(1)` is bit-identical to
    /// [`DenseMatrix::gram`]. Used for one-time setup costs — QuadCache
    /// builds on large dense shards (`worker::local_solver`) — never by
    /// the steady-state round loop.
    pub fn par_gram(&self, threads: usize) -> DenseMatrix {
        let d = self.cols;
        let t = threads.max(1).min(self.rows.max(1));
        if t <= 1 {
            return self.gram();
        }
        // Fixed chunking: chunk i covers base rows, the first `rem`
        // chunks one extra — a pure function of (rows, t).
        let (base, rem) = (self.rows / t, self.rows % t);
        let mut bounds = Vec::with_capacity(t + 1);
        bounds.push(0usize);
        for i in 0..t {
            bounds.push(bounds[i] + base + usize::from(i < rem));
        }
        let mut partials: Vec<DenseMatrix> = Vec::with_capacity(t);
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..t)
                .map(|i| {
                    let (r0, r1) = (bounds[i], bounds[i + 1]);
                    s.spawn(move || {
                        let mut p = DenseMatrix::zeros(d, d);
                        self.gram_acc_rows(r0, r1, &mut p);
                        p
                    })
                })
                .collect();
            for h in handles {
                partials.push(h.join().expect("par_gram worker panicked"));
            }
        });
        // Fixed-order reduction: chunk 0 seeds, the rest accumulate.
        let mut g = partials.remove(0);
        for p in &partials {
            ops::axpy(1.0, &p.data, &mut g.data);
        }
        mirror_upper_to_lower(&mut g);
        g
    }

    /// The previous 2-row register-blocked Gram, kept verbatim as the
    /// before-kernel for `hotpath_micro`'s old-vs-new comparison and as a
    /// reference implementation for the kernel parity tests.
    pub fn gram_2row(&self) -> DenseMatrix {
        let d = self.cols;
        let mut g = DenseMatrix::zeros(d, d);
        let pairs = self.rows / 2;
        for p in 0..pairs {
            let (r0, r1) = (self.row(2 * p), self.row(2 * p + 1));
            for a in 0..d {
                let (ra0, ra1) = (r0[a], r1[a]);
                if ra0 == 0.0 && ra1 == 0.0 {
                    continue;
                }
                let grow = &mut g.row_mut(a)[a..];
                let (t0, t1) = (&r0[a..], &r1[a..]);
                for b in 0..grow.len() {
                    grow[b] += ra0 * t0[b] + ra1 * t1[b];
                }
            }
        }
        if self.rows % 2 == 1 {
            let r = self.row(self.rows - 1);
            for a in 0..d {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = &mut g.row_mut(a)[a..];
                let t = &r[a..];
                for b in 0..grow.len() {
                    grow[b] += ra * t[b];
                }
            }
        }
        mirror_upper_to_lower(&mut g);
        g
    }

    /// Accumulate X[row0..row1]^T X[row0..row1]'s *upper triangle* into
    /// `g`. Column blocks are outer so a panel's block chunks (at most
    /// 8 x 128 f64 = 8 KiB) stay in L1 across the whole feature loop;
    /// within a block, rows are consumed in panels of 8/4/2/1. The
    /// per-entry accumulation order depends only on the row range and
    /// the sequential microkernel, never on how the remainder decomposes
    /// into sub-panels (see [`ops::axpy_panel`]) — appending zero rows
    /// is bit-exact, the invariant padded shards rely on.
    fn gram_acc_rows(&self, row0: usize, row1: usize, g: &mut DenseMatrix) {
        let d = self.cols;
        debug_assert_eq!(g.rows, d);
        debug_assert!(row1 <= self.rows && row0 <= row1);
        for b0 in (0..d).step_by(GRAM_COL_BLOCK) {
            let b1 = (b0 + GRAM_COL_BLOCK).min(d);
            let mut r = row0;
            while r + GRAM_PANEL_ROWS <= row1 {
                self.gram_panel::<GRAM_PANEL_ROWS>(r, b0, b1, g);
                r += GRAM_PANEL_ROWS;
            }
            if r + 4 <= row1 {
                self.gram_panel::<4>(r, b0, b1, g);
                r += 4;
            }
            if r + 2 <= row1 {
                self.gram_panel::<2>(r, b0, b1, g);
                r += 2;
            }
            if r < row1 {
                self.gram_panel::<1>(r, b0, b1, g);
            }
        }
    }

    /// One (K-row panel) x (column block [b0, b1)) update of the upper
    /// triangle of g.
    #[inline]
    fn gram_panel<const K: usize>(&self, r: usize, b0: usize, b1: usize, g: &mut DenseMatrix) {
        let d = self.cols;
        for a in 0..b1 {
            let lo = a.max(b0);
            let mut coeffs = [0.0f64; K];
            let mut any = false;
            for k in 0..K {
                let c = self.data[(r + k) * d + a];
                coeffs[k] = c;
                any |= c != 0.0;
            }
            if !any {
                continue;
            }
            let rows: [&[f64]; K] =
                std::array::from_fn(|k| &self.data[(r + k) * d + lo..(r + k) * d + b1]);
            let out = &mut g.data[a * d + lo..a * d + b1];
            ops::axpy_panel(&coeffs, &rows, out);
        }
    }

    /// Sub-matrix of the given rows, in order.
    pub fn take_rows(&self, rows: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(rows.len(), self.cols);
        for (k, &i) in rows.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Symmetric matrix-vector product helper used by dense Hessian paths.
    pub fn symv(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(self.rows, self.cols);
        self.matvec(v, out);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        ops::dot(&self.data, &self.data).sqrt()
    }

    /// Spectral norm ||A||_2 of a *symmetric* matrix, by power iteration.
    /// Used by Lemma-2 experiments (max_i ||H_i - H||_2) and tests.
    pub fn sym_spectral_norm(&self, iters: usize, seed: u64) -> f64 {
        debug_assert_eq!(self.rows, self.cols);
        let d = self.cols;
        if d == 0 {
            return 0.0;
        }
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut v: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let n = ops::norm2(&v).max(1e-300);
        ops::scale(1.0 / n, &mut v);
        let mut av = vec![0.0; d];
        let mut lambda = 0.0;
        for _ in 0..iters {
            self.matvec(&v, &mut av);
            let n = ops::norm2(&av);
            if n == 0.0 {
                return 0.0;
            }
            lambda = n;
            for j in 0..d {
                v[j] = av[j] / n;
            }
        }
        // |lambda| of the dominant eigenvalue; for symmetric A this is
        // the spectral norm.
        lambda
    }

    /// self + alpha * I (fresh copy). Square matrices only.
    pub fn add_diag(&self, alpha: f64) -> DenseMatrix {
        debug_assert_eq!(self.rows, self.cols);
        let mut m = self.clone();
        for i in 0..self.rows {
            let v = m.get(i, i) + alpha;
            m.set(i, i, v);
        }
        m
    }

    /// self += alpha * other (same shape).
    pub fn add_scaled(&mut self, alpha: f64, other: &DenseMatrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        ops::axpy(alpha, &other.data, &mut self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> DenseMatrix {
        DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ])
    }

    #[test]
    fn matvec() {
        let mut out = vec![0.0; 3];
        a().matvec(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn rmatvec() {
        let mut out = vec![0.0; 2];
        a().rmatvec(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let g = a().gram();
        // A^T A = [[35, 44], [44, 56]]
        assert_eq!(g.get(0, 0), 35.0);
        assert_eq!(g.get(0, 1), 44.0);
        assert_eq!(g.get(1, 0), 44.0);
        assert_eq!(g.get(1, 1), 56.0);
    }

    fn random(n: usize, d: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut m = DenseMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        m
    }

    #[test]
    fn blocked_gram_matches_2row_reference() {
        for &(n, d) in &[(1usize, 1usize), (3, 2), (7, 5), (16, 8), (33, 17), (64, 130)] {
            let m = random(n, d, 7 + n as u64 + d as u64);
            let g = m.gram();
            let r = m.gram_2row();
            for a in 0..d {
                for b in 0..d {
                    let (x, y) = (g.get(a, b), r.get(a, b));
                    assert!(
                        (x - y).abs() <= 1e-12 * x.abs().max(y.abs()).max(1.0),
                        "({n}x{d}) [{a},{b}]: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn gram_padding_rows_are_bit_exact() {
        // appending all-zero rows must not perturb a single bit, whatever
        // panel decomposition the new row count lands on
        let m = random(5, 9, 3);
        let g = m.gram();
        for pad in 1..=9usize {
            let mut rows: Vec<Vec<f64>> = (0..5).map(|i| m.row(i).to_vec()).collect();
            rows.extend(std::iter::repeat(vec![0.0; 9]).take(pad));
            let padded = DenseMatrix::from_rows(&rows);
            assert_eq!(g.data(), padded.gram().data(), "pad={pad}");
        }
    }

    #[test]
    fn par_gram_is_deterministic_and_matches_serial() {
        let m = random(37, 13, 11);
        let g = m.gram();
        // t=1 is the serial kernel verbatim
        assert_eq!(g.data(), m.par_gram(1).data());
        for t in [2usize, 3, 5, 8, 64] {
            let p1 = m.par_gram(t);
            let p2 = m.par_gram(t);
            // bit-reproducible for a fixed thread count
            assert_eq!(p1.data(), p2.data(), "t={t}");
            for a in 0..13 {
                for b in 0..13 {
                    let (x, y) = (p1.get(a, b), g.get(a, b));
                    assert!(
                        (x - y).abs() <= 1e-12 * x.abs().max(1.0),
                        "t={t} [{a},{b}]: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn gram_empty_and_degenerate_shapes() {
        assert_eq!(DenseMatrix::zeros(0, 4).gram().data(), &[0.0; 16][..]);
        assert_eq!(DenseMatrix::zeros(4, 0).gram().rows(), 0);
        let one = DenseMatrix::from_rows(&[vec![3.0]]);
        assert_eq!(one.gram().get(0, 0), 9.0);
        assert_eq!(one.par_gram(4).get(0, 0), 9.0);
    }

    #[test]
    fn take_rows_reorders() {
        let t = a().take_rows(&[2, 0]);
        assert_eq!(t.row(0), &[5.0, 6.0]);
        assert_eq!(t.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn spectral_norm_diagonal() {
        let mut m = DenseMatrix::eye(3);
        m.set(1, 1, -7.0);
        let s = m.sym_spectral_norm(200, 1);
        assert!((s - 7.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn add_diag_and_scaled() {
        let mut g = a().gram();
        let g2 = g.add_diag(1.0);
        assert_eq!(g2.get(0, 0), 36.0);
        assert_eq!(g2.get(0, 1), 44.0);
        g.add_scaled(2.0, &DenseMatrix::eye(2));
        assert_eq!(g.get(1, 1), 58.0);
    }
}
