//! Dense row-major matrix.
//!
//! Row-major because every access pattern in the stack is row-streamed:
//! matvec walks rows, rmatvec accumulates row-scaled contributions, the
//! Gram product is a rank-1 accumulation per row. This matches the L1
//! Pallas kernel, which streams (block_rows, d) tiles of X through VMEM.

use super::ops;

/// Dense n x d matrix, row-major contiguous storage.
#[derive(Debug, Clone, PartialEq)]
pub struct DenseMatrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl DenseMatrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DenseMatrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// From a contiguous row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        DenseMatrix { rows, cols, data }
    }

    /// From explicit row vectors (tests & tiny fixtures).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        DenseMatrix { rows: r, cols: c, data }
    }

    /// d x d identity.
    pub fn eye(d: usize) -> Self {
        let mut m = Self::zeros(d, d);
        for i in 0..d {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row i as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row i.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// out = A v  (row-streamed; one dot per row).
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            out[i] = ops::dot(self.row(i), v);
        }
    }

    /// out = A^T u  (row-streamed accumulation).
    pub fn rmatvec(&self, u: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        self.rmatvec_acc(u, out);
    }

    /// out += A^T u
    pub fn rmatvec_acc(&self, u: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let ui = u[i];
            if ui != 0.0 {
                ops::axpy(ui, self.row(i), out);
            }
        }
    }

    /// Gram matrix A^T A (d x d), accumulated two rows at a time — a
    /// single pass over A, mirroring the L1 kernel's streamed schedule.
    /// Exploits symmetry (upper triangle computed, then mirrored) and
    /// 2-row register blocking: each pass over a g-row consumes two data
    /// rows, halving the dominant g-row traffic (EXPERIMENTS.md §Perf).
    pub fn gram(&self) -> DenseMatrix {
        let d = self.cols;
        let mut g = DenseMatrix::zeros(d, d);
        let pairs = self.rows / 2;
        for p in 0..pairs {
            let (r0, r1) = (self.row(2 * p), self.row(2 * p + 1));
            for a in 0..d {
                let (ra0, ra1) = (r0[a], r1[a]);
                if ra0 == 0.0 && ra1 == 0.0 {
                    continue;
                }
                let grow = &mut g.row_mut(a)[a..];
                let (t0, t1) = (&r0[a..], &r1[a..]);
                for b in 0..grow.len() {
                    grow[b] += ra0 * t0[b] + ra1 * t1[b];
                }
            }
        }
        if self.rows % 2 == 1 {
            let r = self.row(self.rows - 1);
            for a in 0..d {
                let ra = r[a];
                if ra == 0.0 {
                    continue;
                }
                let grow = &mut g.row_mut(a)[a..];
                let t = &r[a..];
                for b in 0..grow.len() {
                    grow[b] += ra * t[b];
                }
            }
        }
        for a in 0..d {
            for b in (a + 1)..d {
                let v = g.get(a, b);
                g.set(b, a, v);
            }
        }
        g
    }

    /// Sub-matrix of the given rows, in order.
    pub fn take_rows(&self, rows: &[usize]) -> DenseMatrix {
        let mut out = DenseMatrix::zeros(rows.len(), self.cols);
        for (k, &i) in rows.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Symmetric matrix-vector product helper used by dense Hessian paths.
    pub fn symv(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(self.rows, self.cols);
        self.matvec(v, out);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        ops::dot(&self.data, &self.data).sqrt()
    }

    /// Spectral norm ||A||_2 of a *symmetric* matrix, by power iteration.
    /// Used by Lemma-2 experiments (max_i ||H_i - H||_2) and tests.
    pub fn sym_spectral_norm(&self, iters: usize, seed: u64) -> f64 {
        debug_assert_eq!(self.rows, self.cols);
        let d = self.cols;
        if d == 0 {
            return 0.0;
        }
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut v: Vec<f64> = (0..d).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let n = ops::norm2(&v).max(1e-300);
        ops::scale(1.0 / n, &mut v);
        let mut av = vec![0.0; d];
        let mut lambda = 0.0;
        for _ in 0..iters {
            self.matvec(&v, &mut av);
            let n = ops::norm2(&av);
            if n == 0.0 {
                return 0.0;
            }
            lambda = n;
            for j in 0..d {
                v[j] = av[j] / n;
            }
        }
        // |lambda| of the dominant eigenvalue; for symmetric A this is
        // the spectral norm.
        lambda
    }

    /// self + alpha * I (fresh copy). Square matrices only.
    pub fn add_diag(&self, alpha: f64) -> DenseMatrix {
        debug_assert_eq!(self.rows, self.cols);
        let mut m = self.clone();
        for i in 0..self.rows {
            let v = m.get(i, i) + alpha;
            m.set(i, i, v);
        }
        m
    }

    /// self += alpha * other (same shape).
    pub fn add_scaled(&mut self, alpha: f64, other: &DenseMatrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        ops::axpy(alpha, &other.data, &mut self.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a() -> DenseMatrix {
        DenseMatrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![5.0, 6.0],
        ])
    }

    #[test]
    fn matvec() {
        let mut out = vec![0.0; 3];
        a().matvec(&[1.0, -1.0], &mut out);
        assert_eq!(out, vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn rmatvec() {
        let mut out = vec![0.0; 2];
        a().rmatvec(&[1.0, 1.0, 1.0], &mut out);
        assert_eq!(out, vec![9.0, 12.0]);
    }

    #[test]
    fn gram_matches_explicit() {
        let g = a().gram();
        // A^T A = [[35, 44], [44, 56]]
        assert_eq!(g.get(0, 0), 35.0);
        assert_eq!(g.get(0, 1), 44.0);
        assert_eq!(g.get(1, 0), 44.0);
        assert_eq!(g.get(1, 1), 56.0);
    }

    #[test]
    fn take_rows_reorders() {
        let t = a().take_rows(&[2, 0]);
        assert_eq!(t.row(0), &[5.0, 6.0]);
        assert_eq!(t.row(1), &[1.0, 2.0]);
    }

    #[test]
    fn spectral_norm_diagonal() {
        let mut m = DenseMatrix::eye(3);
        m.set(1, 1, -7.0);
        let s = m.sym_spectral_norm(200, 1);
        assert!((s - 7.0).abs() < 1e-6, "{s}");
    }

    #[test]
    fn add_diag_and_scaled() {
        let mut g = a().gram();
        let g2 = g.add_diag(1.0);
        assert_eq!(g2.get(0, 0), 36.0);
        assert_eq!(g2.get(0, 1), 44.0);
        g.add_scaled(2.0, &DenseMatrix::eye(2));
        assert_eq!(g.get(1, 1), 58.0);
    }
}
