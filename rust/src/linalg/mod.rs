//! Dense + sparse linear algebra substrate.
//!
//! The paper's experiments need: dense row-major matrices (synthetic ridge,
//! covtype-like, mnist47-like), CSR sparse matrices (astro-ph-like,
//! ~10^4-dimensional bag-of-words features), a Cholesky factorization for
//! exact local quadratic solves, and conjugate gradient over an abstract
//! operator for the Hessian-free path ("no Hessians are explicitly
//! computed!"). Everything is `f64`, no BLAS dependency — the hot loops are
//! written to autovectorize (see EXPERIMENTS.md §Perf): Gram assembly is
//! tiled (row panels x column blocks over the [`ops::axpy_panel`]
//! microkernel, with a deterministic multi-threaded variant in
//! [`DenseMatrix::par_gram`]) and the Cholesky factorization is blocked
//! right-looking so its inner loops are contiguous [`ops::dot`]s.

pub mod cg;
pub mod cholesky;
pub mod dense;
pub mod ops;
pub mod sparse;

pub use cg::{cg_solve, CgOutcome, LinearOperator};
pub use cholesky::CholeskyFactor;
pub use dense::DenseMatrix;
pub use sparse::CsrMatrix;

use crate::{Error, Result};

/// A feature matrix that is either dense or sparse, with a unified
/// interface for the operations the optimization stack needs.
///
/// Rows are samples, columns are features (n x d).
///
/// `PartialEq` is representation-exact (dense == dense, sparse ==
/// sparse, never across): shard-identity checks compare without
/// densifying, and a dense/sparse mix-up is a bug worth failing on.
#[derive(Debug, Clone, PartialEq)]
pub enum DataMatrix {
    Dense(DenseMatrix),
    Sparse(CsrMatrix),
}

impl DataMatrix {
    pub fn rows(&self) -> usize {
        match self {
            DataMatrix::Dense(m) => m.rows(),
            DataMatrix::Sparse(m) => m.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            DataMatrix::Dense(m) => m.cols(),
            DataMatrix::Sparse(m) => m.cols(),
        }
    }

    /// out = X v   (out: n, v: d)
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) -> Result<()> {
        self.check_dims(v.len(), out.len(), "matvec")?;
        match self {
            DataMatrix::Dense(m) => m.matvec(v, out),
            DataMatrix::Sparse(m) => m.matvec(v, out),
        }
        Ok(())
    }

    /// [`DataMatrix::matvec`] with a deterministic thread count: sparse
    /// matrices fan rows out over `threads` fixed chunks
    /// ([`CsrMatrix::par_matvec`], bit-identical to the serial kernel
    /// for any count); dense matrices take the serial kernel — their
    /// matvec is not the scale bottleneck this path exists for.
    pub fn par_matvec(&self, v: &[f64], out: &mut [f64], threads: usize) -> Result<()> {
        self.check_dims(v.len(), out.len(), "par_matvec")?;
        match self {
            DataMatrix::Dense(m) => m.matvec(v, out),
            DataMatrix::Sparse(m) => m.par_matvec(v, out, threads),
        }
        Ok(())
    }

    /// ||row_i||^2 without materializing the row densely.
    pub fn row_sq_norm(&self, i: usize) -> f64 {
        match self {
            DataMatrix::Dense(m) => {
                let r = m.row(i);
                ops::dot(r, r)
            }
            DataMatrix::Sparse(m) => m.row_sq_norm(i),
        }
    }

    /// out = X^T u   (out: d, u: n)
    pub fn rmatvec(&self, u: &[f64], out: &mut [f64]) -> Result<()> {
        self.check_dims(out.len(), u.len(), "rmatvec")?;
        match self {
            DataMatrix::Dense(m) => m.rmatvec(u, out),
            DataMatrix::Sparse(m) => m.rmatvec(u, out),
        }
        Ok(())
    }

    /// out += X^T u without zeroing out first.
    pub fn rmatvec_acc(&self, u: &[f64], out: &mut [f64]) -> Result<()> {
        self.check_dims(out.len(), u.len(), "rmatvec_acc")?;
        match self {
            DataMatrix::Dense(m) => m.rmatvec_acc(u, out),
            DataMatrix::Sparse(m) => m.rmatvec_acc(u, out),
        }
        Ok(())
    }

    /// The dense Gram matrix X^T X (d x d). Used by the cached-Cholesky
    /// local solver when d is small; CG avoids this entirely.
    pub fn gram(&self) -> DenseMatrix {
        match self {
            DataMatrix::Dense(m) => m.gram(),
            DataMatrix::Sparse(m) => m.gram(),
        }
    }

    /// Extract a sub-matrix containing the given rows (in order).
    pub fn take_rows(&self, rows: &[usize]) -> DataMatrix {
        match self {
            DataMatrix::Dense(m) => DataMatrix::Dense(m.take_rows(rows)),
            DataMatrix::Sparse(m) => DataMatrix::Sparse(m.take_rows(rows)),
        }
    }

    /// Dot product of row i with v (v: d).
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        match self {
            DataMatrix::Dense(m) => ops::dot(m.row(i), v),
            DataMatrix::Sparse(m) => m.row_dot(i, v),
        }
    }

    /// out += alpha * row_i  (out: d)
    pub fn row_axpy(&self, i: usize, alpha: f64, out: &mut [f64]) {
        match self {
            DataMatrix::Dense(m) => ops::axpy(alpha, m.row(i), out),
            DataMatrix::Sparse(m) => m.row_axpy(i, alpha, out),
        }
    }

    /// Densify (tests / small problems only).
    pub fn to_dense(&self) -> DenseMatrix {
        match self {
            DataMatrix::Dense(m) => m.clone(),
            DataMatrix::Sparse(m) => m.to_dense(),
        }
    }

    fn check_dims(&self, d: usize, n: usize, what: &str) -> Result<()> {
        if d != self.cols() || n != self.rows() {
            return Err(Error::Shape(format!(
                "{what}: matrix is {}x{}, got d-vec {d}, n-vec {n}",
                self.rows(),
                self.cols()
            )));
        }
        Ok(())
    }
}

impl From<DenseMatrix> for DataMatrix {
    fn from(m: DenseMatrix) -> Self {
        DataMatrix::Dense(m)
    }
}

impl From<CsrMatrix> for DataMatrix {
    fn from(m: CsrMatrix) -> Self {
        DataMatrix::Sparse(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> (DataMatrix, DataMatrix) {
        let d = DenseMatrix::from_rows(&[
            vec![1.0, 0.0, 2.0],
            vec![0.0, 3.0, 0.0],
            vec![4.0, 0.0, 5.0],
            vec![0.0, 0.0, 6.0],
        ]);
        let s = CsrMatrix::from_dense(&d, 0.0);
        (DataMatrix::Dense(d), DataMatrix::Sparse(s))
    }

    #[test]
    fn dense_sparse_matvec_agree() {
        let (d, s) = small();
        let v = vec![1.0, -2.0, 0.5];
        let mut od = vec![0.0; 4];
        let mut os = vec![0.0; 4];
        d.matvec(&v, &mut od).unwrap();
        s.matvec(&v, &mut os).unwrap();
        assert_eq!(od, os);
    }

    #[test]
    fn dense_sparse_rmatvec_agree() {
        let (d, s) = small();
        let u = vec![1.0, 2.0, 3.0, -1.0];
        let mut od = vec![0.0; 3];
        let mut os = vec![0.0; 3];
        d.rmatvec(&u, &mut od).unwrap();
        s.rmatvec(&u, &mut os).unwrap();
        assert_eq!(od, os);
    }

    #[test]
    fn dense_sparse_gram_agree() {
        let (d, s) = small();
        let gd = d.gram();
        let gs = s.gram();
        for i in 0..3 {
            for j in 0..3 {
                assert!((gd.get(i, j) - gs.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn row_ops_agree() {
        let (d, s) = small();
        let v = vec![1.0, 1.0, 1.0];
        for i in 0..4 {
            assert_eq!(d.row_dot(i, &v), s.row_dot(i, &v));
        }
        let mut od = vec![0.0; 3];
        let mut os = vec![0.0; 3];
        d.row_axpy(2, 2.0, &mut od);
        s.row_axpy(2, 2.0, &mut os);
        assert_eq!(od, os);
    }

    #[test]
    fn par_matvec_and_row_sq_agree_across_representations() {
        let (d, s) = small();
        let v = vec![1.0, -2.0, 0.5];
        let mut serial = vec![0.0; 4];
        d.matvec(&v, &mut serial).unwrap();
        for t in [1usize, 3, 16] {
            let mut od = vec![0.0; 4];
            let mut os = vec![0.0; 4];
            d.par_matvec(&v, &mut od, t).unwrap();
            s.par_matvec(&v, &mut os, t).unwrap();
            assert_eq!(od, serial, "dense t={t}");
            assert_eq!(os, serial, "sparse t={t}");
        }
        for i in 0..4 {
            assert_eq!(d.row_sq_norm(i), s.row_sq_norm(i), "row {i}");
        }
    }

    #[test]
    fn take_rows_agree() {
        let (d, s) = small();
        let idx = [3usize, 0];
        let dd = d.take_rows(&idx).to_dense();
        let ss = s.take_rows(&idx).to_dense();
        assert_eq!(dd.row(0), ss.row(0));
        assert_eq!(dd.row(1), ss.row(1));
        assert_eq!(dd.rows(), 2);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (d, _) = small();
        let mut out = vec![0.0; 4];
        assert!(d.matvec(&[1.0, 2.0], &mut out).is_err());
    }
}
