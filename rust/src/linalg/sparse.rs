//! Compressed sparse row (CSR) matrix.
//!
//! The ASTRO-PH-like workload (paper figs. 3-4) is ~10^4-dimensional with
//! ~50 nonzeros per row; dense storage would be 100x waste and, more
//! importantly, the smooth-hinge HVP X^T D X v must cost O(nnz), not
//! O(n d), for the local Newton-CG solves to be realistic.

use super::dense::DenseMatrix;

/// CSR sparse matrix (n x d).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointers, len = rows + 1.
    indptr: Vec<usize>,
    /// Column indices, len = nnz, sorted within each row.
    indices: Vec<u32>,
    /// Values, len = nnz.
    data: Vec<f64>,
}

impl CsrMatrix {
    /// Build from raw CSR components (validated).
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr tail");
        assert_eq!(indices.len(), data.len(), "indices/data length");
        for w in indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be nondecreasing");
        }
        for &j in &indices {
            assert!((j as usize) < cols, "column index out of range");
        }
        CsrMatrix { rows, cols, indptr, indices, data }
    }

    /// Build from a (row, col, value) triplet list.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Self {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
        for &(i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet out of range");
            per_row[i].push((j, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|&(j, _)| j);
            for &(j, v) in row.iter() {
                indices.push(j as u32);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, indices, data }
    }

    /// Sparsify a dense matrix, dropping |v| <= threshold.
    pub fn from_dense(m: &DenseMatrix, threshold: f64) -> Self {
        let mut trips = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > threshold {
                    trips.push((i, j, v));
                }
            }
        }
        Self::from_triplets(m.rows(), m.cols(), &trips)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// (indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// out = X v
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            out[i] = self.row_dot(i, v);
        }
    }

    /// Dot of row i with a dense vector.
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.row(i);
        let mut acc = 0.0;
        for k in 0..idx.len() {
            acc += val[k] * v[idx[k] as usize];
        }
        acc
    }

    /// out += alpha * row_i
    #[inline]
    pub fn row_axpy(&self, i: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.row(i);
        for k in 0..idx.len() {
            out[idx[k] as usize] += alpha * val[k];
        }
    }

    /// out = X^T u
    pub fn rmatvec(&self, u: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        self.rmatvec_acc(u, out);
    }

    /// out += X^T u
    pub fn rmatvec_acc(&self, u: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let ui = u[i];
            if ui != 0.0 {
                self.row_axpy(i, ui, out);
            }
        }
    }

    /// Dense Gram matrix X^T X. Only sane for moderate d; the sparse
    /// workloads use CG + row ops instead (cost O(nnz) per HVP).
    pub fn gram(&self) -> DenseMatrix {
        let mut g = DenseMatrix::zeros(self.cols, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for a in 0..idx.len() {
                let (ja, va) = (idx[a] as usize, val[a]);
                for b in 0..idx.len() {
                    let (jb, vb) = (idx[b] as usize, val[b]);
                    let cur = g.get(ja, jb);
                    g.set(ja, jb, cur + va * vb);
                }
            }
        }
        g
    }

    /// Sub-matrix of the given rows, in order.
    pub fn take_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (idx, val) = self.row(i);
            indices.extend_from_slice(idx);
            data.extend_from_slice(val);
            indptr.push(indices.len());
        }
        CsrMatrix { rows: rows.len(), cols: self.cols, indptr, indices, data }
    }

    /// Densify (tests / padding for the PJRT path).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                m.set(i, idx[k] as usize, val[k]);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, -1.0), (1, 0, 5.0), (2, 2, 3.0), (2, 3, 4.0)],
        )
    }

    #[test]
    fn structure() {
        let m = x();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(0), (&[1u32, 3][..], &[2.0, -1.0][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[5.0][..]));
    }

    #[test]
    fn matvec_roundtrip_dense() {
        let m = x();
        let d = m.to_dense();
        let v = vec![1.0, 2.0, 3.0, 4.0];
        let mut o1 = vec![0.0; 3];
        let mut o2 = vec![0.0; 3];
        m.matvec(&v, &mut o1);
        d.matvec(&v, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn rmatvec_roundtrip_dense() {
        let m = x();
        let d = m.to_dense();
        let u = vec![1.0, -2.0, 0.5];
        let mut o1 = vec![0.0; 4];
        let mut o2 = vec![0.0; 4];
        m.rmatvec(&u, &mut o1);
        d.rmatvec(&u, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn gram_roundtrip_dense() {
        let m = x();
        let gd = m.to_dense().gram();
        let gs = m.gram();
        for i in 0..4 {
            for j in 0..4 {
                assert!((gd.get(i, j) - gs.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn take_rows_subset() {
        let m = x().take_rows(&[2, 2, 0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(0), m.row(1));
        assert_eq!(m.row(2), (&[1u32, 3][..], &[2.0, -1.0][..]));
    }

    #[test]
    #[should_panic(expected = "column index out of range")]
    fn rejects_bad_indices() {
        CsrMatrix::new(1, 2, vec![0, 1], vec![5], vec![1.0]);
    }

    #[test]
    fn from_dense_threshold() {
        let d = DenseMatrix::from_rows(&[vec![0.0, 1e-12, 3.0]]);
        let s = CsrMatrix::from_dense(&d, 1e-9);
        assert_eq!(s.nnz(), 1);
    }
}
