//! Compressed sparse row (CSR) matrix.
//!
//! The ASTRO-PH-like workload (paper figs. 3-4) is ~10^4-dimensional with
//! ~50 nonzeros per row; dense storage would be 100x waste and, more
//! importantly, the smooth-hinge HVP X^T D X v must cost O(nnz), not
//! O(n d), for the local Newton-CG solves to be realistic.

use super::dense::DenseMatrix;

/// CSR sparse matrix (n x d).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    rows: usize,
    cols: usize,
    /// Row pointers, len = rows + 1.
    indptr: Vec<usize>,
    /// Column indices, len = nnz, sorted within each row.
    indices: Vec<u32>,
    /// Values, len = nnz.
    data: Vec<f64>,
}

impl CsrMatrix {
    /// Build from raw CSR components (validated).
    pub fn new(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<u32>,
        data: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr length");
        assert_eq!(*indptr.last().unwrap_or(&0), indices.len(), "indptr tail");
        assert_eq!(indices.len(), data.len(), "indices/data length");
        for w in indptr.windows(2) {
            assert!(w[0] <= w[1], "indptr must be nondecreasing");
        }
        for &j in &indices {
            assert!((j as usize) < cols, "column index out of range");
        }
        CsrMatrix { rows, cols, indptr, indices, data }
    }

    /// Build from a (row, col, value) triplet list.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Self {
        let mut per_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
        for &(i, j, v) in triplets {
            assert!(i < rows && j < cols, "triplet out of range");
            per_row[i].push((j, v));
        }
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for row in per_row.iter_mut() {
            row.sort_by_key(|&(j, _)| j);
            for &(j, v) in row.iter() {
                indices.push(j as u32);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        CsrMatrix { rows, cols, indptr, indices, data }
    }

    /// Sparsify a dense matrix, dropping |v| <= threshold.
    pub fn from_dense(m: &DenseMatrix, threshold: f64) -> Self {
        let mut trips = Vec::new();
        for i in 0..m.rows() {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v.abs() > threshold {
                    trips.push((i, j, v));
                }
            }
        }
        Self::from_triplets(m.rows(), m.cols(), &trips)
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// (indices, values) of row i.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[s..e], &self.data[s..e])
    }

    /// out = X v
    pub fn matvec(&self, v: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            out[i] = self.row_dot(i, v);
        }
    }

    /// Deterministic multi-threaded `out = X v`: rows are split into
    /// `threads` fixed contiguous chunks (same chunking as
    /// [`super::DenseMatrix::par_gram`] — chunk i covers `base` rows, the
    /// first `rem` chunks one extra) and each chunk fills its own
    /// disjoint slice of `out` with the identical per-row [`Self::row_dot`]
    /// the serial kernel uses. Because no element is ever reduced across
    /// threads, the result is **bit-identical to [`Self::matvec`] for any
    /// thread count**, not just reproducible per count — engine parity
    /// survives whatever `t` a worker picks. Used for one-time setup and
    /// bench sweeps; the steady-state CG loop stays serial per worker.
    pub fn par_matvec(&self, v: &[f64], out: &mut [f64], threads: usize) {
        let t = threads.max(1).min(self.rows.max(1));
        if t <= 1 {
            self.matvec(v, out);
            return;
        }
        let (base, rem) = (self.rows / t, self.rows % t);
        std::thread::scope(|s| {
            let mut rest = &mut out[..self.rows];
            let mut r0 = 0usize;
            for i in 0..t {
                let len = base + usize::from(i < rem);
                let (chunk, tail) = rest.split_at_mut(len);
                rest = tail;
                let start = r0;
                s.spawn(move || {
                    for (k, o) in chunk.iter_mut().enumerate() {
                        *o = self.row_dot(start + k, v);
                    }
                });
                r0 += len;
            }
        });
    }

    /// ||row_i||^2 — O(nnz_i), no densification. The harness and the
    /// workers' `RowSq` reply use this so sparse datasets never build a
    /// dense copy just to compute eta (paper Lemma 1 scaling).
    ///
    /// Canonical 4-lane fold (see [`super::ops`] module docs): lanes
    /// `a0..a3`, combine `(a0 + a2) + (a1 + a3)`, sequential remainder —
    /// deterministic for every engine and thread count.
    #[inline]
    pub fn row_sq_norm(&self, i: usize) -> f64 {
        let (_, val) = self.row(i);
        let n = val.len();
        let chunks = n / 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let k = 4 * c;
            a0 += val[k] * val[k];
            a1 += val[k + 1] * val[k + 1];
            a2 += val[k + 2] * val[k + 2];
            a3 += val[k + 3] * val[k + 3];
        }
        let mut acc = (a0 + a2) + (a1 + a3);
        for &v in &val[4 * chunks..] {
            acc += v * v;
        }
        acc
    }

    /// Dot of row i with a dense vector.
    ///
    /// The sparse counterpart of [`super::ops::dot`] and the inner loop
    /// of every sparse matvec / Hessian-vector product: the same
    /// canonical 4-lane fold over the row's nonzeros, with the gathers
    /// `v[idx[k]]` feeding four independent accumulators so the O(nnz)
    /// CG iterations aren't serialized on one FP dependency chain.
    #[inline]
    pub fn row_dot(&self, i: usize, v: &[f64]) -> f64 {
        let (idx, val) = self.row(i);
        let n = idx.len();
        let chunks = n / 4;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for c in 0..chunks {
            let k = 4 * c;
            a0 += val[k] * v[idx[k] as usize];
            a1 += val[k + 1] * v[idx[k + 1] as usize];
            a2 += val[k + 2] * v[idx[k + 2] as usize];
            a3 += val[k + 3] * v[idx[k + 3] as usize];
        }
        let mut acc = (a0 + a2) + (a1 + a3);
        for k in 4 * chunks..n {
            acc += val[k] * v[idx[k] as usize];
        }
        acc
    }

    /// out += alpha * row_i
    #[inline]
    pub fn row_axpy(&self, i: usize, alpha: f64, out: &mut [f64]) {
        let (idx, val) = self.row(i);
        for k in 0..idx.len() {
            out[idx[k] as usize] += alpha * val[k];
        }
    }

    /// out = X^T u
    pub fn rmatvec(&self, u: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        self.rmatvec_acc(u, out);
    }

    /// out += X^T u
    pub fn rmatvec_acc(&self, u: &[f64], out: &mut [f64]) {
        for i in 0..self.rows {
            let ui = u[i];
            if ui != 0.0 {
                self.row_axpy(i, ui, out);
            }
        }
    }

    /// Dense Gram matrix X^T X. Only sane for moderate d; the sparse
    /// workloads use CG + row ops instead (cost O(nnz) per HVP).
    pub fn gram(&self) -> DenseMatrix {
        let mut g = DenseMatrix::zeros(self.cols, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for a in 0..idx.len() {
                let (ja, va) = (idx[a] as usize, val[a]);
                for b in 0..idx.len() {
                    let (jb, vb) = (idx[b] as usize, val[b]);
                    let cur = g.get(ja, jb);
                    g.set(ja, jb, cur + va * vb);
                }
            }
        }
        g
    }

    /// Sub-matrix of the given rows, in order.
    pub fn take_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for &i in rows {
            let (idx, val) = self.row(i);
            indices.extend_from_slice(idx);
            data.extend_from_slice(val);
            indptr.push(indices.len());
        }
        CsrMatrix { rows: rows.len(), cols: self.cols, indptr, indices, data }
    }

    /// Densify (tests / padding for the PJRT path).
    pub fn to_dense(&self) -> DenseMatrix {
        let mut m = DenseMatrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (idx, val) = self.row(i);
            for k in 0..idx.len() {
                m.set(i, idx[k] as usize, val[k]);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x() -> CsrMatrix {
        CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (0, 3, -1.0), (1, 0, 5.0), (2, 2, 3.0), (2, 3, 4.0)],
        )
    }

    #[test]
    fn structure() {
        let m = x();
        assert_eq!(m.nnz(), 5);
        assert_eq!(m.row(0), (&[1u32, 3][..], &[2.0, -1.0][..]));
        assert_eq!(m.row(1), (&[0u32][..], &[5.0][..]));
    }

    #[test]
    fn matvec_roundtrip_dense() {
        let m = x();
        let d = m.to_dense();
        let v = vec![1.0, 2.0, 3.0, 4.0];
        let mut o1 = vec![0.0; 3];
        let mut o2 = vec![0.0; 3];
        m.matvec(&v, &mut o1);
        d.matvec(&v, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn par_matvec_bitwise_matches_serial_for_any_thread_count() {
        // build a ragged sparse matrix large enough that every chunking
        // in the t-sweep is non-trivial
        let mut trips = Vec::new();
        let (n, d) = (37usize, 13usize);
        for i in 0..n {
            for k in 0..(i % 5) {
                let j = (i * 7 + k * 3) % d;
                trips.push((i, j, (i as f64 - 2.0 * k as f64) * 0.37 + 0.1));
            }
        }
        let m = CsrMatrix::from_triplets(n, d, &trips);
        let v: Vec<f64> = (0..d).map(|j| (j as f64 * 0.71) - 1.3).collect();
        let mut serial = vec![0.0; n];
        m.matvec(&v, &mut serial);
        for t in [1usize, 2, 3, 5, 8, 64] {
            let mut par = vec![f64::NAN; n];
            m.par_matvec(&v, &mut par, t);
            assert_eq!(par, serial, "t={t}");
        }
    }

    #[test]
    fn row_sq_norm_matches_row_dot() {
        let m = x();
        for i in 0..3 {
            let (_, val) = m.row(i);
            let expect: f64 = val.iter().map(|v| v * v).sum();
            assert_eq!(m.row_sq_norm(i), expect);
        }
        // empty row: zero
        let e = CsrMatrix::from_triplets(2, 3, &[(0, 1, 2.0)]);
        assert_eq!(e.row_sq_norm(1), 0.0);
    }

    #[test]
    fn row_kernels_match_canonical_lane_fold() {
        // a 10-nnz row exercises both the 4-lane body and the remainder;
        // the fold order (ops.rs module docs) is pinned bit-for-bit
        let trips: Vec<(usize, usize, f64)> =
            (0..10).map(|k| (0usize, k * 2, 0.3 * k as f64 - 0.7)).collect();
        let m = CsrMatrix::from_triplets(1, 20, &trips);
        let v: Vec<f64> = (0..20).map(|j| (j as f64) * 0.11 - 0.5).collect();
        let (idx, val) = m.row(0);
        let lane_fold = |f: &dyn Fn(usize) -> f64| {
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);
            let chunks = idx.len() / 4;
            for c in 0..chunks {
                let k = 4 * c;
                a0 += f(k);
                a1 += f(k + 1);
                a2 += f(k + 2);
                a3 += f(k + 3);
            }
            let mut acc = (a0 + a2) + (a1 + a3);
            for k in 4 * chunks..idx.len() {
                acc += f(k);
            }
            acc
        };
        let expect_dot = lane_fold(&|k| val[k] * v[idx[k] as usize]);
        let expect_sq = lane_fold(&|k| val[k] * val[k]);
        assert_eq!(m.row_dot(0, &v).to_bits(), expect_dot.to_bits());
        assert_eq!(m.row_sq_norm(0).to_bits(), expect_sq.to_bits());
    }

    #[test]
    fn rmatvec_roundtrip_dense() {
        let m = x();
        let d = m.to_dense();
        let u = vec![1.0, -2.0, 0.5];
        let mut o1 = vec![0.0; 4];
        let mut o2 = vec![0.0; 4];
        m.rmatvec(&u, &mut o1);
        d.rmatvec(&u, &mut o2);
        assert_eq!(o1, o2);
    }

    #[test]
    fn gram_roundtrip_dense() {
        let m = x();
        let gd = m.to_dense().gram();
        let gs = m.gram();
        for i in 0..4 {
            for j in 0..4 {
                assert!((gd.get(i, j) - gs.get(i, j)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn take_rows_subset() {
        let m = x().take_rows(&[2, 2, 0]);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.row(0), m.row(1));
        assert_eq!(m.row(2), (&[1u32, 3][..], &[2.0, -1.0][..]));
    }

    #[test]
    #[should_panic(expected = "column index out of range")]
    fn rejects_bad_indices() {
        CsrMatrix::new(1, 2, vec![0, 1], vec![5], vec![1.0]);
    }

    #[test]
    fn from_dense_threshold() {
        let d = DenseMatrix::from_rows(&[vec![0.0, 1e-12, 3.0]]);
        let s = CsrMatrix::from_dense(&d, 1e-9);
        assert_eq!(s.nnz(), 1);
    }
}
