//! Conjugate gradient over an abstract linear operator.
//!
//! This is the rust twin of the `_cg` loop in `python/compile/model.py`:
//! the Hessian-free path of every DANE local solve. The operator is
//! abstract so the same loop serves the ridge Gram operator
//! (1/n) X^T X + (lam+mu) I, the smooth-hinge weighted Gram operator
//! (1/n) X^T D X + (lam+mu) I (cost O(nnz) on sparse shards), and dense
//! test fixtures. The loop is allocation-free after setup — scratch
//! buffers live in [`CgScratch`] and are reused across rounds.

use super::ops;
use crate::{Error, Result};

/// A symmetric positive definite linear map v -> A v.
pub trait LinearOperator {
    /// Dimension of the (square) operator.
    fn dim(&self) -> usize;
    /// out = A v. Must not allocate on the hot path.
    fn apply(&self, v: &[f64], out: &mut [f64]);
}

/// A dense symmetric matrix as an operator (tests, small problems).
impl LinearOperator for super::dense::DenseMatrix {
    fn dim(&self) -> usize {
        debug_assert_eq!(self.rows(), self.cols());
        self.rows()
    }

    fn apply(&self, v: &[f64], out: &mut [f64]) {
        self.matvec(v, out);
    }
}

/// Result metadata of a CG solve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CgOutcome {
    /// Iterations actually performed.
    pub iters: usize,
    /// Final ||r|| / ||b||.
    pub rel_residual: f64,
    /// Whether the tolerance was met within the budget.
    pub converged: bool,
}

/// Reusable scratch space for [`cg_solve`]; allocate once per worker.
#[derive(Debug, Clone)]
pub struct CgScratch {
    r: Vec<f64>,
    p: Vec<f64>,
    ap: Vec<f64>,
}

impl CgScratch {
    pub fn new(d: usize) -> Self {
        CgScratch { r: vec![0.0; d], p: vec![0.0; d], ap: vec![0.0; d] }
    }

    fn ensure(&mut self, d: usize) {
        if self.r.len() != d {
            *self = CgScratch::new(d);
        }
    }
}

/// Solve A x = b with CG from x = 0, relative tolerance `tol` on ||r||/||b||.
///
/// `x` is overwritten with the solution. Returns the outcome; an error is
/// only raised on shape mismatch or a breakdown (p^T A p <= 0, i.e. the
/// operator was not SPD).
pub fn cg_solve(
    a: &dyn LinearOperator,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iters: usize,
    scratch: &mut CgScratch,
) -> Result<CgOutcome> {
    let d = a.dim();
    if b.len() != d || x.len() != d {
        return Err(Error::Shape(format!(
            "cg: operator dim {d}, b {}, x {}",
            b.len(),
            x.len()
        )));
    }
    scratch.ensure(d);
    let CgScratch { r, p, ap } = scratch;

    x.fill(0.0);
    r.copy_from_slice(b);
    p.copy_from_slice(b);
    let bnorm = ops::norm2(b);
    if bnorm == 0.0 {
        return Ok(CgOutcome { iters: 0, rel_residual: 0.0, converged: true });
    }
    let stop = tol * bnorm;
    let mut rs = ops::dot(r, r);

    let mut iters = 0;
    while iters < max_iters && rs.sqrt() > stop {
        a.apply(p, ap);
        let pap = ops::dot(p, ap);
        if pap <= 0.0 {
            return Err(Error::Numerical(format!(
                "cg breakdown at iter {iters}: p^T A p = {pap:.3e} (operator not SPD)"
            )));
        }
        let alpha = rs / pap;
        ops::axpy(alpha, p, x);
        ops::axpy(-alpha, ap, r);
        let rs_new = ops::dot(r, r);
        ops::axpby(1.0, r, rs_new / rs, p);
        rs = rs_new;
        iters += 1;
    }

    Ok(CgOutcome {
        iters,
        rel_residual: rs.sqrt() / bnorm,
        converged: rs.sqrt() <= stop,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::DenseMatrix;
    use crate::linalg::cholesky::CholeskyFactor;

    fn spd(d: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut b = DenseMatrix::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                b.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        b.gram().add_diag(0.5)
    }

    #[test]
    fn cg_matches_cholesky() {
        let a = spd(25, 11);
        let b: Vec<f64> = (0..25).map(|i| (i as f64).cos()).collect();
        let chol = CholeskyFactor::factor(&a).unwrap();
        let x_ref = chol.solve(&b);
        let mut x = vec![0.0; 25];
        let mut s = CgScratch::new(25);
        let out = cg_solve(&a, &b, &mut x, 1e-12, 500, &mut s).unwrap();
        assert!(out.converged, "{out:?}");
        for i in 0..25 {
            assert!((x[i] - x_ref[i]).abs() < 1e-8);
        }
    }

    #[test]
    fn cg_identity_one_step() {
        let a = DenseMatrix::eye(8);
        let b = vec![1.0; 8];
        let mut x = vec![0.0; 8];
        let mut s = CgScratch::new(8);
        let out = cg_solve(&a, &b, &mut x, 1e-12, 100, &mut s).unwrap();
        assert_eq!(out.iters, 1);
        assert_eq!(x, b);
    }

    #[test]
    fn cg_zero_rhs() {
        let a = spd(5, 2);
        let b = vec![0.0; 5];
        let mut x = vec![1.0; 5];
        let mut s = CgScratch::new(5);
        let out = cg_solve(&a, &b, &mut x, 1e-10, 10, &mut s).unwrap();
        assert!(out.converged);
        assert_eq!(x, vec![0.0; 5]);
    }

    #[test]
    fn cg_budget_respected() {
        let a = spd(40, 5);
        let b = vec![1.0; 40];
        let mut x = vec![0.0; 40];
        let mut s = CgScratch::new(40);
        let out = cg_solve(&a, &b, &mut x, 1e-30, 3, &mut s).unwrap();
        assert_eq!(out.iters, 3);
        assert!(!out.converged);
    }

    #[test]
    fn cg_rejects_non_spd() {
        let mut a = DenseMatrix::eye(4);
        a.set(2, 2, -1.0);
        let b = vec![0.0, 0.0, 1.0, 0.0];
        let mut x = vec![0.0; 4];
        let mut s = CgScratch::new(4);
        assert!(cg_solve(&a, &b, &mut x, 1e-10, 50, &mut s).is_err());
    }

    #[test]
    fn cg_shape_mismatch() {
        let a = spd(4, 1);
        let b = vec![1.0; 3];
        let mut x = vec![0.0; 4];
        let mut s = CgScratch::new(4);
        assert!(cg_solve(&a, &b, &mut x, 1e-10, 50, &mut s).is_err());
    }

    #[test]
    fn cg_terminates_at_dim_steps() {
        // Exact termination property: <= d iterations to machine precision.
        let a = spd(15, 9);
        let b: Vec<f64> = (0..15).map(|i| 1.0 + i as f64).collect();
        let mut x = vec![0.0; 15];
        let mut s = CgScratch::new(15);
        let out = cg_solve(&a, &b, &mut x, 1e-10, 15, &mut s).unwrap();
        assert!(out.converged, "{out:?}");
    }
}
