//! Cholesky factorization for SPD systems.
//!
//! The exact local-quadratic DANE solver factors (H_i + mu I) **once** per
//! run and back-substitutes every round — the Hessian of a quadratic shard
//! never changes, so this turns each DANE iteration into two O(d^2)
//! triangular solves instead of an O(d^3) solve or an O(d^2)-per-CG-step
//! iteration. This is the main L3 hot-path optimization (EXPERIMENTS.md
//! §Perf).
//!
//! The factorization is *blocked right-looking*: columns are processed in
//! panels of [`CHOL_BLOCK`], and the trailing submatrix is updated once
//! per panel with a rank-[`CHOL_BLOCK`] correction whose inner loop is a
//! contiguous [`ops::dot`] — the 4-lane unrolled kernel LLVM
//! autovectorizes — instead of the strictly-sequential scalar reduction
//! of the unblocked scheme (kept as [`CholeskyFactor::factor_unblocked`]
//! for benches and parity tests). The factor is stored twice, row-major L
//! *and* row-major L^T, so both triangular solves stream contiguous
//! memory.

use super::dense::DenseMatrix;
use super::ops;
use crate::{Error, Result};

/// Panel width of the blocked factorization. 64 columns x 8 bytes = 512 B
/// per row segment; the panel's rank-k trailing update then runs dot
/// products of length 64 — long enough to vectorize, short enough that
/// two row segments always sit in L1.
const CHOL_BLOCK: usize = 64;

/// Lower-triangular Cholesky factor L with A = L L^T.
#[derive(Debug, Clone)]
pub struct CholeskyFactor {
    d: usize,
    /// Row-major lower-triangular factor (full d x d storage; the upper
    /// triangle is unused — simpler indexing beats the halved memory for
    /// the d <= few-thousand regime this crate targets).
    l: Vec<f64>,
    /// Row-major copy of L^T (upper-triangular), so the backward solve
    /// L^T x = y streams rows contiguously instead of walking columns of
    /// `l` with stride d (EXPERIMENTS.md §Perf).
    lt: Vec<f64>,
}

impl CholeskyFactor {
    /// Factor an SPD matrix with the blocked right-looking scheme. Fails
    /// with [`Error::Numerical`] when a pivot is not strictly positive
    /// (matrix not SPD to working precision).
    pub fn factor(a: &DenseMatrix) -> Result<Self> {
        let d = a.rows();
        if d != a.cols() {
            return Err(Error::Shape("cholesky: matrix not square".into()));
        }
        // Seed l with the lower triangle of a; the upper stays zero.
        let mut l = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..=i {
                l[i * d + j] = a.get(i, j);
            }
        }
        let mut k0 = 0;
        while k0 < d {
            let k1 = (k0 + CHOL_BLOCK).min(d);
            // 1. Panel factorization (columns k0..k1, rows k0..d). All
            // corrections from columns < k0 were applied by earlier
            // trailing updates, so only within-panel dots remain.
            for j in k0..k1 {
                let s = l[j * d + j] - ops::dot(&l[j * d + k0..j * d + j], &l[j * d + k0..j * d + j]);
                if s <= 0.0 {
                    return Err(Error::Numerical(format!(
                        "cholesky pivot {j} nonpositive ({s:.3e}); matrix not SPD"
                    )));
                }
                let ljj = s.sqrt();
                l[j * d + j] = ljj;
                for i in (j + 1)..d {
                    let s = l[i * d + j]
                        - ops::dot(&l[i * d + k0..i * d + j], &l[j * d + k0..j * d + j]);
                    l[i * d + j] = s / ljj;
                }
            }
            // 2. Trailing update: A22 -= L21 L21^T, one dot of length
            // (k1 - k0) per updated entry — the flops-dominant SYRK.
            for i in k1..d {
                for j in k1..=i {
                    let s = ops::dot(&l[i * d + k0..i * d + k1], &l[j * d + k0..j * d + k1]);
                    l[i * d + j] -= s;
                }
            }
            k0 = k1;
        }
        let lt = transpose_lower(&l, d);
        Ok(CholeskyFactor { d, l, lt })
    }

    /// The previous unblocked factorization, kept verbatim as the
    /// before-kernel for `hotpath_micro`'s old-vs-new comparison and as
    /// a reference for the kernel parity tests.
    pub fn factor_unblocked(a: &DenseMatrix) -> Result<Self> {
        let d = a.rows();
        if d != a.cols() {
            return Err(Error::Shape("cholesky: matrix not square".into()));
        }
        let mut l = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..=i {
                let mut s = a.get(i, j);
                // s -= sum_k L[i,k] * L[j,k]
                let (ri, rj) = (&l[i * d..i * d + j], &l[j * d..j * d + j]);
                for k in 0..j {
                    s -= ri[k] * rj[k];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::Numerical(format!(
                            "cholesky pivot {i} nonpositive ({s:.3e}); matrix not SPD"
                        )));
                    }
                    l[i * d + j] = s.sqrt();
                } else {
                    l[i * d + j] = s / l[j * d + j];
                }
            }
        }
        let lt = transpose_lower(&l, d);
        Ok(CholeskyFactor { d, l, lt })
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Solve A x = b in place (b becomes x): forward then backward
    /// substitution. O(d^2), allocation-free; both sweeps are contiguous
    /// [`ops::dot`]s (forward over rows of L, backward over rows of L^T).
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let d = self.d;
        debug_assert_eq!(b.len(), d);
        // L y = b
        for i in 0..d {
            let s = b[i] - ops::dot(&self.l[i * d..i * d + i], &b[..i]);
            b[i] = s / self.l[i * d + i];
        }
        // L^T x = y, streaming row i of L^T
        for i in (0..d).rev() {
            let s = b[i] - ops::dot(&self.lt[i * d + i + 1..(i + 1) * d], &b[i + 1..]);
            b[i] = s / self.lt[i * d + i];
        }
    }

    /// Solve A x = b into a fresh vector.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// log det(A) = 2 sum_i log L_ii (used by diagnostics).
    pub fn log_det(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.d {
            s += self.l[i * self.d + i].ln();
        }
        2.0 * s
    }
}

/// Row-major L^T from row-major lower-triangular L.
fn transpose_lower(l: &[f64], d: usize) -> Vec<f64> {
    let mut lt = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..=i {
            lt[j * d + i] = l[i * d + j];
        }
    }
    lt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    fn spd(d: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut b = DenseMatrix::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                b.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        // B^T B + I is SPD
        b.gram().add_diag(1.0)
    }

    #[test]
    fn solve_recovers_rhs() {
        let a = spd(12, 7);
        let f = CholeskyFactor::factor(&a).unwrap();
        let x_true: Vec<f64> = (0..12).map(|i| (i as f64) - 5.5).collect();
        let mut b = vec![0.0; 12];
        a.matvec(&x_true, &mut b);
        let x = f.solve(&b);
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-9, "{} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn blocked_matches_unblocked_across_panel_boundaries() {
        // d below, at, just past and well past CHOL_BLOCK
        for &d in &[1usize, 2, 5, 63, 64, 65, 130] {
            let a = spd(d, 40 + d as u64);
            let fb = CholeskyFactor::factor(&a).unwrap();
            let fu = CholeskyFactor::factor_unblocked(&a).unwrap();
            for i in 0..d {
                for j in 0..=i {
                    let (x, y) = (fb.l[i * d + j], fu.l[i * d + j]);
                    assert!(
                        (x - y).abs() <= 1e-10 * x.abs().max(1.0),
                        "d={d} L[{i},{j}]: {x} vs {y}"
                    );
                }
            }
            // and the transposed copy agrees with the factor
            for i in 0..d {
                for j in 0..=i {
                    assert_eq!(fb.lt[j * d + i], fb.l[i * d + j]);
                }
            }
        }
    }

    #[test]
    fn identity_is_noop() {
        let f = CholeskyFactor::factor(&DenseMatrix::eye(5)).unwrap();
        let b = vec![1.0, -2.0, 3.0, 0.0, 4.0];
        assert_eq!(f.solve(&b), b);
        assert!(f.log_det().abs() < 1e-12);
    }

    #[test]
    fn rejects_non_spd() {
        let mut a = DenseMatrix::eye(3);
        a.set(1, 1, -1.0);
        assert!(CholeskyFactor::factor(&a).is_err());
        assert!(CholeskyFactor::factor_unblocked(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let a = DenseMatrix::zeros(2, 3);
        assert!(CholeskyFactor::factor(&a).is_err());
        assert!(CholeskyFactor::factor_unblocked(&a).is_err());
    }

    #[test]
    fn log_det_diagonal() {
        let mut a = DenseMatrix::eye(2);
        a.set(0, 0, 4.0);
        a.set(1, 1, 9.0);
        let f = CholeskyFactor::factor(&a).unwrap();
        assert!((f.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_matches_residual() {
        let a = spd(30, 3);
        let f = CholeskyFactor::factor(&a).unwrap();
        let b: Vec<f64> = (0..30).map(|i| (i as f64).sin()).collect();
        let x = f.solve(&b);
        let mut ax = vec![0.0; 30];
        a.matvec(&x, &mut ax);
        let mut r = vec![0.0; 30];
        ops::sub(&ax, &b, &mut r);
        assert!(ops::norm2(&r) < 1e-9 * ops::norm2(&b).max(1.0));
    }

    #[test]
    fn large_blocked_solve_is_accurate() {
        // d = 150 crosses two panel boundaries; verify the full pipeline
        let d = 150;
        let a = spd(d, 9);
        let f = CholeskyFactor::factor(&a).unwrap();
        let x_true: Vec<f64> = (0..d).map(|i| ((i * 7 % 13) as f64) - 6.0).collect();
        let mut b = vec![0.0; d];
        a.matvec(&x_true, &mut b);
        let x = f.solve(&b);
        let err: f64 = x
            .iter()
            .zip(&x_true)
            .map(|(u, v)| (u - v) * (u - v))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-8 * ops::norm2(&x_true), "err {err}");
    }
}
