//! Cholesky factorization for SPD systems.
//!
//! The exact local-quadratic DANE solver factors (H_i + mu I) **once** per
//! run and back-substitutes every round — the Hessian of a quadratic shard
//! never changes, so this turns each DANE iteration into two O(d^2)
//! triangular solves instead of an O(d^3) solve or an O(d^2)-per-CG-step
//! iteration. This is the main L3 hot-path optimization (EXPERIMENTS.md
//! §Perf).

use super::dense::DenseMatrix;
use crate::{Error, Result};

/// Lower-triangular Cholesky factor L with A = L L^T.
#[derive(Debug, Clone)]
pub struct CholeskyFactor {
    d: usize,
    /// Row-major lower-triangular factor (full d x d storage; the upper
    /// triangle is unused — simpler indexing beats the halved memory for
    /// the d <= few-thousand regime this crate targets).
    l: Vec<f64>,
}

impl CholeskyFactor {
    /// Factor an SPD matrix. Fails with [`Error::Numerical`] when a pivot
    /// is not strictly positive (matrix not SPD to working precision).
    pub fn factor(a: &DenseMatrix) -> Result<Self> {
        let d = a.rows();
        if d != a.cols() {
            return Err(Error::Shape("cholesky: matrix not square".into()));
        }
        let mut l = vec![0.0; d * d];
        for i in 0..d {
            for j in 0..=i {
                let mut s = a.get(i, j);
                // s -= sum_k L[i,k] * L[j,k]
                let (ri, rj) = (&l[i * d..i * d + j], &l[j * d..j * d + j]);
                for k in 0..j {
                    s -= ri[k] * rj[k];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::Numerical(format!(
                            "cholesky pivot {i} nonpositive ({s:.3e}); matrix not SPD"
                        )));
                    }
                    l[i * d + j] = s.sqrt();
                } else {
                    l[i * d + j] = s / l[j * d + j];
                }
            }
        }
        Ok(CholeskyFactor { d, l })
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Solve A x = b in place (b becomes x): forward then backward
    /// substitution. O(d^2), allocation-free.
    pub fn solve_in_place(&self, b: &mut [f64]) {
        let d = self.d;
        debug_assert_eq!(b.len(), d);
        // L y = b
        for i in 0..d {
            let mut s = b[i];
            let row = &self.l[i * d..i * d + i];
            for k in 0..i {
                s -= row[k] * b[k];
            }
            b[i] = s / self.l[i * d + i];
        }
        // L^T x = y
        for i in (0..d).rev() {
            let mut s = b[i];
            for k in (i + 1)..d {
                s -= self.l[k * d + i] * b[k];
            }
            b[i] = s / self.l[i * d + i];
        }
    }

    /// Solve A x = b into a fresh vector.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// log det(A) = 2 sum_i log L_ii (used by diagnostics).
    pub fn log_det(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.d {
            s += self.l[i * self.d + i].ln();
        }
        2.0 * s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::ops;

    fn spd(d: usize, seed: u64) -> DenseMatrix {
        let mut rng = crate::util::Rng64::seed_from_u64(seed);
        let mut b = DenseMatrix::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                b.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        // B^T B + I is SPD
        b.gram().add_diag(1.0)
    }

    #[test]
    fn solve_recovers_rhs() {
        let a = spd(12, 7);
        let f = CholeskyFactor::factor(&a).unwrap();
        let x_true: Vec<f64> = (0..12).map(|i| (i as f64) - 5.5).collect();
        let mut b = vec![0.0; 12];
        a.matvec(&x_true, &mut b);
        let x = f.solve(&b);
        for i in 0..12 {
            assert!((x[i] - x_true[i]).abs() < 1e-9, "{} vs {}", x[i], x_true[i]);
        }
    }

    #[test]
    fn identity_is_noop() {
        let f = CholeskyFactor::factor(&DenseMatrix::eye(5)).unwrap();
        let b = vec![1.0, -2.0, 3.0, 0.0, 4.0];
        assert_eq!(f.solve(&b), b);
        assert!(f.log_det().abs() < 1e-12);
    }

    #[test]
    fn rejects_non_spd() {
        let mut a = DenseMatrix::eye(3);
        a.set(1, 1, -1.0);
        assert!(CholeskyFactor::factor(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        let a = DenseMatrix::zeros(2, 3);
        assert!(CholeskyFactor::factor(&a).is_err());
    }

    #[test]
    fn log_det_diagonal() {
        let mut a = DenseMatrix::eye(2);
        a.set(0, 0, 4.0);
        a.set(1, 1, 9.0);
        let f = CholeskyFactor::factor(&a).unwrap();
        assert!((f.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_matches_residual() {
        let a = spd(30, 3);
        let f = CholeskyFactor::factor(&a).unwrap();
        let b: Vec<f64> = (0..30).map(|i| (i as f64).sin()).collect();
        let x = f.solve(&b);
        let mut ax = vec![0.0; 30];
        a.matvec(&x, &mut ax);
        let mut r = vec![0.0; 30];
        ops::sub(&ax, &b, &mut r);
        assert!(ops::norm2(&r) < 1e-9 * ops::norm2(&b).max(1.0));
    }
}
