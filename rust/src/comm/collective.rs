//! Averaging collectives with full accounting.

use crate::linalg::ops;
use super::netmodel::NetModel;

/// Cumulative communication statistics for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CommStats {
    /// Synchronous communication rounds (one allreduce or broadcast = 1).
    pub rounds: u64,
    /// Total payload bytes moved across the (simulated) network,
    /// topology-independent: sum over participants of their payload.
    pub bytes: u64,
    /// Modeled wallclock seconds under the attached [`NetModel`].
    pub modeled_seconds: f64,
    /// Bytes *measured on a real transport* (frame bytes written to and
    /// read from sockets by the TCP engine, instrumentation rounds
    /// included). Exactly zero on the in-memory engines — the
    /// modeled-vs-measured pair is the point of the column.
    pub wire_bytes: u64,
    /// What `wire_bytes` *would have been* had every compressed round
    /// frame carried its uncompressed f64 payload: `wire_bytes` plus
    /// the per-frame savings of the active codec (see
    /// [`crate::comm::compress`]). Equal to `wire_bytes` exactly when
    /// `codec: none` — that identity is the CI trust anchor for the
    /// column — and zero on the in-memory engines, like `wire_bytes`
    /// itself. The compression ratio of a window is
    /// `payload_bytes_raw / wire_bytes`.
    pub payload_bytes_raw: u64,
    /// One-time bring-up bytes measured on a real transport (Init or
    /// InitRef frames, Peers frames, and their acks). O(n·d) when
    /// shards go by value, O(m) when they go by reference
    /// (`--data-by-ref`). Zero on the in-memory engines; never reset
    /// with the per-window round counters.
    pub startup_bytes: u64,
    /// Workers currently answering collectives. Set by the cluster
    /// engines when a snapshot is taken (`Cluster::comm_stats`), not
    /// accumulated here — equal to `machines` on a fault-free run and
    /// under `respawn`; drops below it when a `degrade` policy
    /// quarantines a dead rank. 0 in raw `Collective`-level stats that
    /// never passed through an engine.
    pub alive_workers: u64,
    /// Successful fault recoveries (respawn/redial or quorum
    /// degradation) performed so far. Set by the supervision layer when
    /// a snapshot is taken; 0 on fault-free runs.
    pub recoveries: u64,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.rounds += other.rounds;
        self.bytes += other.bytes;
        self.modeled_seconds += other.modeled_seconds;
        self.wire_bytes += other.wire_bytes;
        self.payload_bytes_raw += other.payload_bytes_raw;
        self.startup_bytes += other.startup_bytes;
        // Snapshot fields, not counters: a merged window reports the
        // last snapshot's quorum and the total recoveries across
        // windows.
        self.alive_workers = other.alive_workers;
        self.recoveries += other.recoveries;
    }
}

/// The collective operations the coordinator uses. One instance per run;
/// it owns the stats and the network model.
#[derive(Debug, Clone)]
pub struct Collective {
    stats: CommStats,
    net: NetModel,
}

impl Collective {
    pub fn new(net: NetModel) -> Self {
        Collective { stats: CommStats::default(), net }
    }

    /// Free local-only collective (m = 1 degenerate runs).
    pub fn noop() -> Self {
        Collective::new(NetModel::free())
    }

    pub fn stats(&self) -> &CommStats {
        &self.stats
    }

    pub fn reset(&mut self) {
        self.stats = CommStats::default();
    }

    /// Overwrite the cumulative stats wholesale — checkpoint resume
    /// continues the crashed run's accounting instead of starting at 0.
    pub fn restore(&mut self, stats: &CommStats) {
        self.stats = stats.clone();
    }

    /// Allreduce-mean over per-worker vectors: every worker contributes a
    /// d-vector, everyone ends with the mean. Counts ONE round. The
    /// result is written into `out`.
    pub fn allreduce_mean(&mut self, contributions: &[&[f64]], out: &mut [f64]) {
        assert!(!contributions.is_empty(), "allreduce with no participants");
        let d = out.len();
        for c in contributions {
            assert_eq!(c.len(), d, "allreduce length mismatch");
        }
        ops::mean_into(contributions, out);
        self.account(contributions.len(), d);
    }

    /// Allreduce-mean of scalars (loss values). Counts ONE round — in a
    /// real deployment scalars piggyback on a vector allreduce, so callers
    /// that average a vector and a scalar in the same logical round should
    /// use [`Collective::allreduce_mean_with_scalar`] instead.
    pub fn allreduce_scalar_mean(&mut self, xs: &[f64]) -> f64 {
        assert!(!xs.is_empty(), "allreduce with no participants");
        let m = xs.len();
        let mean = xs.iter().sum::<f64>() / m as f64;
        self.account(m, 1);
        mean
    }

    /// One round that averages a vector and a scalar together (gradient +
    /// loss share an allreduce; payload is d+1 values per worker).
    pub fn allreduce_mean_with_scalar(
        &mut self,
        contributions: &[&[f64]],
        scalars: &[f64],
        out: &mut [f64],
    ) -> f64 {
        assert_eq!(contributions.len(), scalars.len());
        assert!(!contributions.is_empty(), "allreduce with no participants");
        let d = out.len();
        ops::mean_into(contributions, out);
        let mean = scalars.iter().sum::<f64>() / scalars.len() as f64;
        self.account(contributions.len(), d + 1);
        mean
    }

    /// Broadcast a d-vector from the leader to all m workers. Counts ONE
    /// round. (The data is shared memory in this simulation; only the
    /// accounting happens here.)
    pub fn broadcast(&mut self, m: usize, d: usize) {
        self.account(m, d);
    }

    /// Account ONE allreduce round of a `d`-value f64 payload per worker
    /// where the reduction itself was computed by the caller (e.g. the
    /// n_i-weighted gradient means the serial cluster performs inline).
    pub fn count_round(&mut self, m: usize, d: usize) {
        self.account(m, d);
    }

    fn account(&mut self, m: usize, d: usize) {
        let payload = (d * std::mem::size_of::<f64>()) as u64;
        self.stats.rounds += 1;
        self.stats.bytes += payload * m as u64;
        self.stats.modeled_seconds += self.net.collective_seconds(m, payload);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::netmodel::{NetModel, Topology};

    #[test]
    fn allreduce_is_serial_mean() {
        let mut c = Collective::noop();
        let a = vec![1.0, 2.0];
        let b = vec![3.0, 4.0];
        let mut out = vec![0.0; 2];
        c.allreduce_mean(&[&a, &b], &mut out);
        assert_eq!(out, vec![2.0, 3.0]);
        assert_eq!(c.stats().rounds, 1);
        assert_eq!(c.stats().bytes, 2 * 2 * 8);
    }

    #[test]
    fn scalar_mean_counts_round() {
        let mut c = Collective::noop();
        let m = c.allreduce_scalar_mean(&[1.0, 3.0]);
        assert_eq!(m, 2.0);
        assert_eq!(c.stats().rounds, 1);
    }

    #[test]
    fn fused_vector_scalar_single_round() {
        let mut c = Collective::noop();
        let a = vec![2.0];
        let b = vec![4.0];
        let mut out = vec![0.0];
        let s = c.allreduce_mean_with_scalar(&[&a, &b], &[10.0, 20.0], &mut out);
        assert_eq!(out, vec![3.0]);
        assert_eq!(s, 15.0);
        assert_eq!(c.stats().rounds, 1);
        assert_eq!(c.stats().bytes, 2 * 2 * 8);
    }

    #[test]
    fn modeled_time_accumulates() {
        let net = NetModel::new(1e-3, 1e-9, Topology::Star);
        let mut c = Collective::new(net);
        let a = vec![0.0; 1000];
        let mut out = vec![0.0; 1000];
        c.allreduce_mean(&[&a, &a, &a, &a], &mut out);
        assert!(c.stats().modeled_seconds > 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_lengths_rejected() {
        let mut c = Collective::noop();
        let a = vec![1.0, 2.0];
        let b = vec![3.0];
        let mut out = vec![0.0; 2];
        c.allreduce_mean(&[&a, &b], &mut out);
    }

    #[test]
    fn reset_clears_stats() {
        let mut c = Collective::noop();
        c.broadcast(4, 10);
        assert_eq!(c.stats().rounds, 1);
        c.reset();
        assert_eq!(c.stats(), &CommStats::default());
    }
}
