//! Alpha-beta network cost model.
//!
//! A collective over m nodes with per-node payload B bytes is modeled as
//!
//! ```text
//! T = steps(topology, m) * alpha + traffic(topology, m, B) * beta
//! ```
//!
//! with `alpha` the per-message latency and `beta` the inverse bandwidth
//! (seconds/byte). This is the standard LogP-lite model used to reason
//! about allreduce algorithms; it lets the benches report a modeled
//! wallclock for each algorithm's communication pattern on cluster-like
//! parameters (e.g. alpha = 50us, beta = 1/1GBps), which is how the
//! paper's "communication is expensive" premise becomes quantitative.

/// Collective algorithm / topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Leader gathers then scatters through its single link: the root
    /// sequentially receives m-1 payloads, then sequentially sends m-1 —
    /// 2(m-1) steps and 2(m-1)B traffic on the critical path.
    Star,
    /// Ring allreduce: 2(m-1) steps, each moving B/m per link.
    Ring,
    /// Binomial tree reduce + broadcast: 2 log2(m) steps, B per link.
    Tree,
}

/// Latency/bandwidth parameters + topology.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetModel {
    /// Per-step latency, seconds.
    pub alpha: f64,
    /// Inverse bandwidth, seconds per byte.
    pub beta: f64,
    pub topology: Topology,
}

impl NetModel {
    pub fn new(alpha: f64, beta: f64, topology: Topology) -> Self {
        assert!(alpha >= 0.0 && beta >= 0.0);
        NetModel { alpha, beta, topology }
    }

    /// Zero-cost model (pure iteration counting).
    pub fn free() -> Self {
        NetModel { alpha: 0.0, beta: 0.0, topology: Topology::Star }
    }

    /// A datacenter-like default: 50us latency, 10 Gbit/s links.
    pub fn datacenter() -> Self {
        NetModel { alpha: 50e-6, beta: 8.0 / 10e9, topology: Topology::Ring }
    }

    /// Modeled seconds for one allreduce/broadcast over m nodes with
    /// per-node payload `bytes`.
    pub fn collective_seconds(&self, m: usize, bytes: u64) -> f64 {
        if m <= 1 {
            return 0.0;
        }
        let b = bytes as f64;
        let m_f = m as f64;
        let (steps, traffic) = match self.topology {
            // Root sequentially receives m-1 payloads then sends m-1:
            // both the latency term and the traffic serialize at the
            // root, so both scale with (m-1).
            Topology::Star => (2.0 * (m_f - 1.0), 2.0 * (m_f - 1.0) * b),
            // Classic ring allreduce: 2(m-1) steps of B/m each.
            Topology::Ring => (2.0 * (m_f - 1.0), 2.0 * (m_f - 1.0) * b / m_f),
            // Binomial tree: up + down, B per step on the critical path.
            Topology::Tree => {
                let l = m_f.log2().ceil();
                (2.0 * l, 2.0 * l * b)
            }
        };
        steps * self.alpha + traffic * self.beta
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_is_free() {
        let n = NetModel::datacenter();
        assert_eq!(n.collective_seconds(1, 1 << 20), 0.0);
    }

    #[test]
    fn ring_beats_star_bandwidth_at_scale() {
        // For large payloads the ring's B/m per-step traffic wins.
        let bytes = 64 << 20;
        let star = NetModel::new(0.0, 1e-9, Topology::Star);
        let ring = NetModel::new(0.0, 1e-9, Topology::Ring);
        assert!(
            ring.collective_seconds(64, bytes) < star.collective_seconds(64, bytes)
        );
    }

    #[test]
    fn star_latency_serializes_at_the_root() {
        // The old 2-step star model under-charged the root's sequential
        // receive/send; with the serialization modeled, star's latency
        // term grows linearly in m, exactly tying ring's 2(m-1) steps —
        // and any bandwidth cost then breaks the tie in ring's favor
        // (B/m per ring step vs the full B through the root).
        let star = NetModel::new(50e-6, 0.0, Topology::Star);
        let ring = NetModel::new(50e-6, 0.0, Topology::Ring);
        assert_eq!(star.collective_seconds(64, 8), ring.collective_seconds(64, 8));
        assert_eq!(
            star.collective_seconds(64, 8) / star.collective_seconds(2, 8),
            63.0,
            "star latency must scale with (m-1)"
        );
        let star_b = NetModel::new(50e-6, 1e-9, Topology::Star);
        let ring_b = NetModel::new(50e-6, 1e-9, Topology::Ring);
        assert!(
            ring_b.collective_seconds(64, 8) < star_b.collective_seconds(64, 8),
            "with bandwidth charged, ring wins even for tiny payloads"
        );
    }

    #[test]
    fn tree_beats_star_and_ring_latency_for_tiny_payloads() {
        // The regime where a latency-optimal topology genuinely wins
        // tiny payloads at scale is the logarithmic one: 2 log2(m)
        // steps vs 2(m-1) for both the (serialized) star and the ring.
        let alpha = 50e-6;
        let tree = NetModel::new(alpha, 0.0, Topology::Tree);
        let star = NetModel::new(alpha, 0.0, Topology::Star);
        let ring = NetModel::new(alpha, 0.0, Topology::Ring);
        assert!(tree.collective_seconds(64, 8) < star.collective_seconds(64, 8));
        assert!(tree.collective_seconds(64, 8) < ring.collective_seconds(64, 8));
    }

    #[test]
    fn tree_scales_logarithmically() {
        let tree = NetModel::new(1.0, 0.0, Topology::Tree);
        let t64 = tree.collective_seconds(64, 8);
        let t8 = tree.collective_seconds(8, 8);
        assert_eq!(t64, 2.0 * 6.0);
        assert_eq!(t8, 2.0 * 3.0);
    }

    #[test]
    fn monotone_in_m_and_bytes() {
        let n = NetModel::datacenter();
        assert!(n.collective_seconds(4, 1000) <= n.collective_seconds(8, 1000));
        assert!(n.collective_seconds(8, 1000) <= n.collective_seconds(8, 2000));
    }
}
