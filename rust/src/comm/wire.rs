//! The leader <-> worker round protocol as an explicit wire format.
//!
//! Every collective the five algorithms use maps onto one [`Command`]
//! broadcast and one [`Reply`] gather per worker per round. The same
//! typed messages travel over three transports:
//!
//! * `SerialCluster` — no messages at all (inline calls, the degenerate
//!   transport);
//! * `ThreadedCluster` — `Command`/`Reply` values move through the
//!   in-memory rendezvous channel ([`super::roundchan`]), never touching
//!   the codec — broadcast payloads stay behind `Arc`s and reply buffers
//!   recycle, preserving the zero-allocation steady state;
//! * `TcpCluster` — the same values encoded through the binary codec
//!   below and moved over real sockets, with every transmitted byte
//!   counted into `CommStats::wire_bytes`.
//!
//! ## Frame format (version 1)
//!
//! ```text
//! frame   := len(u32 LE, length of body) | body
//! body    := version(u8 = 1) | tag(u8) | payload
//! vec     := count(u64 LE) | count x f64 LE
//! str     := len(u32 LE) | len UTF-8 bytes
//! coded   := codec(u8) | codec-specific payload   (see [`super::compress`])
//! ```
//!
//! `f64` values are moved as their IEEE-754 little-endian bit patterns
//! (`to_le_bytes`/`from_le_bytes`), so NaN payloads and ±inf round-trip
//! bit-exactly — the parity tests rely on the codec never perturbing a
//! value.
//!
//! Decoding is **total**: malformed input (truncated frames, bad version
//! bytes, unknown tags, counts that exceed the received bytes, trailing
//! garbage) returns `Err` — never a panic, never an attacker-sized
//! allocation. [`read_frame`] rejects length prefixes above
//! [`MAX_FRAME_LEN`] before allocating and grows its buffer
//! geometrically as bytes actually arrive, so a hostile prefix costs at
//! most ~2x the bytes actually sent — while a reused buffer retains its
//! capacity across frames, making the steady state (same-size frames
//! round over round) allocation- and zeroing-free.
//!
//! The `out` fields on [`Command::GradLoss`] / [`Command::DaneSolve`] are
//! a transport detail of the threaded engine (the leader loans each
//! worker the reply buffer it must fill); they are **not wire content** —
//! the codec skips them on encode and decodes them as empty.

use super::compress::{self, Codec, CodedVec, CompressedOp, ReplySpec};
use crate::data::Shard;
use crate::linalg::{CsrMatrix, DataMatrix, DenseMatrix};
use crate::{Error, Result};
use std::io::Read;
use std::sync::Arc;

/// Protocol version moved in every frame; bump on any layout change.
pub const WIRE_VERSION: u8 = 1;

/// Hard cap on one frame body (1 GiB). A length prefix above this is
/// rejected before any allocation; real frames (the largest is an
/// [`Command::Init`] carrying a shard) stay far below it.
pub const MAX_FRAME_LEN: usize = 1 << 30;

/// First growth step of [`read_frame`]'s body buffer (bytes). The
/// buffer doubles from here toward the decoded length prefix, resizing
/// only when the bytes already received fill it, so a hostile prefix
/// cannot force a large up-front allocation.
const READ_SEED: usize = 1 << 12;

// ---- tags -----------------------------------------------------------
const CMD_INIT: u8 = 0x01;
const CMD_GRAD_LOSS: u8 = 0x02;
const CMD_LOSS: u8 = 0x03;
const CMD_DANE_SOLVE: u8 = 0x04;
const CMD_PROX: u8 = 0x05;
const CMD_ERM: u8 = 0x06;
const CMD_ROW_SQ: u8 = 0x07;
const CMD_PEERS: u8 = 0x08;
const CMD_PROX_ALL: u8 = 0x09;
const CMD_FOR: u8 = 0x0a;
const CMD_INIT_REF: u8 = 0x0b;
const CMD_COMPRESSED_VEC: u8 = 0x0c;

const REP_VEC: u8 = 0x81;
const REP_SCALAR: u8 = 0x82;
const REP_VEC_SCALAR: u8 = 0x83;
const REP_VEC_PAIR: u8 = 0x84;
const REP_ERR: u8 = 0x85;
const REP_COMPRESSED_VEC: u8 = 0x86;

const MAT_DENSE: u8 = 0;
const MAT_SPARSE: u8 = 1;

// Compressed-payload sub-tags (see [`super::compress`]).
const CODEC_F32: u8 = 1;
const CODEC_TOPK: u8 = 2;
const CODEC_QUANT: u8 = 3;
const OP_GRAD_LOSS: u8 = 1;
const OP_DANE_SOLVE: u8 = 2;

/// One-time worker setup: everything a remote process needs to become a
/// cluster member. In-memory engines construct workers directly and
/// never see this message.
#[derive(Debug, Clone)]
pub struct InitPayload {
    /// Rank of this worker in the cluster.
    pub worker_id: usize,
    /// Objective by name (`config::LossKind::from_name`), so the wire
    /// layer stays decoupled from the config layer.
    pub loss_name: String,
    /// L2 regularization lambda of the objective.
    pub lambda: f64,
    /// Gram-build thread override (config `threads`); must match across
    /// workers and engines for bit-reproducible runs.
    pub gram_threads: Option<usize>,
    /// This worker's slice of the data.
    pub shard: Shard,
}

/// Init **by reference**: instead of the shard's rows, the frame names
/// the dataset file plus the sharding parameters, and the worker
/// recomputes its own row list (`data::shard_indices(n, machines,
/// shard_seed)[worker_id]`) and streams exactly those rows from local
/// disk (`data::libsvm::load_rows`). The frame is O(1) in the data
/// size, so cluster startup traffic through the leader drops from
/// O(n) to O(m) — the point of the by-ref data plane. Requires every
/// worker to see the dataset file at `path` (shared filesystem or
/// pre-staged copy); the deterministic shuffle makes the resulting
/// shard bit-identical to the by-value one.
#[derive(Debug, Clone, PartialEq)]
pub struct InitRefPayload {
    /// Rank of this worker in the cluster.
    pub worker_id: usize,
    /// Objective by name (`config::LossKind::from_name`).
    pub loss_name: String,
    /// L2 regularization lambda of the objective.
    pub lambda: f64,
    /// Gram-build thread override (config `threads`).
    pub gram_threads: Option<usize>,
    /// Dataset file (LIBSVM format) as the worker should open it.
    pub path: String,
    /// The full dataset's feature dimension (leader-authoritative; a
    /// row subset cannot infer it).
    pub dim: usize,
    /// Total data rows in the file — the `n` of the sharding shuffle.
    pub n: usize,
    /// Cluster size — the `m` of the sharding shuffle.
    pub machines: usize,
    /// Seed of the deterministic sharding shuffle
    /// (`cfg.seed.wrapping_add(1)`, same discipline as every engine).
    pub shard_seed: u64,
}

/// One child entry of a [`Command::Peers`] frame: everything a relay
/// node needs to serve one downstream link.
#[derive(Debug, Clone, PartialEq)]
pub struct PeerChild {
    /// The child worker's rank.
    pub rank: usize,
    /// Where to open the worker-to-worker round connection.
    pub addr: String,
    /// Preorder rank list of the child's whole subtree
    /// ([`crate::comm::topology::TreePlan::subtree_ranks`]): both the
    /// number of reply frames to expect from the child each round and
    /// the order they are attributed to ranks on the way up.
    pub ranks: Vec<usize>,
}

/// Tree-relay setup payload (TCP transport only): sent to every worker
/// after Init, before the first round.
#[derive(Debug, Clone, PartialEq)]
pub struct PeersPayload {
    /// Downstream links this worker must open and relay over (empty for
    /// leaves).
    pub children: Vec<PeerChild>,
    /// True when the worker's round-plane parent is another worker: the
    /// leader closes the setup connection after the ack, and the worker
    /// accepts its parent's connection from its listener.
    pub expect_parent: bool,
}

/// Commands the leader broadcasts to workers — the collective surface of
/// the `Cluster` trait, one variant per distinct worker computation.
/// Broadcast payloads (`w`, `w_prev`, `g`) sit behind `Arc` so the
/// threaded engine shares one buffer across all m workers; the codec
/// reads through the `Arc` transparently.
pub enum Command {
    /// Handshake: install the shard + objective (TCP transport only).
    /// Acknowledged with `Reply::Scalar(0.0)`.
    Init(Box<InitPayload>),
    /// Handshake by reference: install the objective and load the
    /// shard from local disk (TCP transport only, `data_by_ref`
    /// config). Acknowledged with `Reply::Scalar(0.0)`.
    InitRef(Box<InitRefPayload>),
    /// grad phi_i + phi_i at w -> `Reply::VecScalar`.
    GradLoss {
        w: Arc<Vec<f64>>,
        /// Leader-loaned reply buffer (threaded transport); not on the wire.
        out: Vec<f64>,
    },
    /// phi_i at w -> `Reply::Scalar`.
    Loss { w: Arc<Vec<f64>> },
    /// DANE local solve (paper eq. 13) -> `Reply::Vec`.
    DaneSolve {
        w_prev: Arc<Vec<f64>>,
        g: Arc<Vec<f64>>,
        eta: f64,
        mu: f64,
        /// Leader-loaned reply buffer (threaded transport); not on the wire.
        out: Vec<f64>,
    },
    /// ADMM proximal step at a per-worker target -> `Reply::Vec`.
    Prox { v: Vec<f64>, rho: f64 },
    /// Local ERM, optionally with a bias-correction subsample
    /// `(r, seed)` -> `Reply::VecPair`.
    Erm { subsample: Option<(f64, u64)> },
    /// Mean squared row norm of the shard -> `Reply::Scalar`.
    RowSq,
    /// Tree-relay setup: which child workers to open round connections
    /// to (TCP transport only). Acknowledged with `Reply::Scalar(0.0)`.
    Peers(Box<PeersPayload>),
    /// ADMM proximal step with *all* per-worker targets broadcast in one
    /// frame: each worker picks `targets[its rank]`. The tree topology's
    /// uniform relay shape for the one per-worker-payload collective
    /// (star topologies keep per-worker [`Command::Prox`] frames) ->
    /// `Reply::Vec`.
    ProxAll { targets: Vec<Vec<f64>>, rho: f64 },
    /// Point-to-point envelope: only worker `rank` executes `inner`;
    /// relay nodes route the frame toward it and pipe the single reply
    /// back up, so a tree round can address one worker without waking
    /// the rest (the Theorem-5 `dane_round_first` path). `inner` must
    /// itself be a compute command — nesting `For` (or the setup
    /// frames) is rejected by the codec.
    For { rank: usize, inner: Box<Command> },
    /// A round command whose O(d) vectors are codec-compressed
    /// ([`super::compress`]): stands in for `GradLoss` or `DaneSolve`,
    /// carries the codec id + params + payload plus the spec the worker
    /// must apply to its reply -> `Reply::CompressedVec`. Behind `Arc`
    /// so the threaded engine broadcasts one compressed payload to all
    /// m workers and tree relays forward it without re-expanding.
    CompressedVec(Arc<compress::CompressedCmd>),
}

impl Command {
    /// Clone for relaying to another worker: broadcast `Arc` payloads
    /// are shared, leader-loaned reply buffers (`out`) never propagate
    /// (each receiver allocates its own reply).
    pub fn relay_copy(&self) -> Command {
        match self {
            Command::Init(p) => Command::Init(p.clone()),
            Command::InitRef(p) => Command::InitRef(p.clone()),
            Command::GradLoss { w, out: _ } => {
                Command::GradLoss { w: w.clone(), out: Vec::new() }
            }
            Command::Loss { w } => Command::Loss { w: w.clone() },
            Command::DaneSolve { w_prev, g, eta, mu, out: _ } => Command::DaneSolve {
                w_prev: w_prev.clone(),
                g: g.clone(),
                eta: *eta,
                mu: *mu,
                out: Vec::new(),
            },
            Command::Prox { v, rho } => Command::Prox { v: v.clone(), rho: *rho },
            Command::Erm { subsample } => Command::Erm { subsample: *subsample },
            Command::RowSq => Command::RowSq,
            Command::Peers(p) => Command::Peers(p.clone()),
            Command::ProxAll { targets, rho } => {
                Command::ProxAll { targets: targets.clone(), rho: *rho }
            }
            Command::For { rank, inner } => {
                Command::For { rank: *rank, inner: Box::new(inner.relay_copy()) }
            }
            Command::CompressedVec(p) => Command::CompressedVec(p.clone()),
        }
    }
}

/// Worker replies, one per command. `Err` carries the worker-side
/// failure message; the leader maps it onto `Error::Runtime`.
pub enum Reply {
    Vec(Vec<f64>),
    Scalar(f64),
    VecScalar(Vec<f64>, f64),
    VecPair(Vec<f64>, Option<Vec<f64>>),
    Err(String),
    /// Codec-compressed result vector plus the scalar local loss when
    /// the operation produces one (the compressed counterpart of
    /// `VecScalar` / `Vec`).
    CompressedVec(Box<compress::CompressedReply>),
}

// ---------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------

/// Encode a full command frame (length prefix included) into `buf`.
/// `buf` is cleared first and reused round over round, so the steady
/// state costs no allocations once it has grown to the round's size.
/// Fails (like the decode side) on a body over [`MAX_FRAME_LEN`] —
/// the length prefix must never wrap or name a frame a peer would
/// reject.
pub fn encode_command(cmd: &Command, buf: &mut Vec<u8>) -> Result<()> {
    begin_frame(buf);
    put_command_body(cmd, buf, true)?;
    end_frame(buf)
}

// ---- raw slice encoders ---------------------------------------------
//
// The TCP leader's allocation-free round path encodes its broadcast
// frames straight from the slices it already holds (`w`, `g`), without
// first constructing an `Arc`-carrying [`Command`] value. Each helper
// below is byte-identical to [`encode_command`] on the equivalent
// command — a test pins the equality, and
// `compress::raw_cmd_frame_len` stays honest against both.

/// [`Command::GradLoss`] frame straight from the weight slice.
pub fn encode_grad_loss_cmd(w: &[f64], buf: &mut Vec<u8>) -> Result<()> {
    begin_frame(buf);
    buf.push(CMD_GRAD_LOSS);
    put_vec(buf, w);
    end_frame(buf)
}

/// [`Command::Loss`] frame straight from the weight slice.
pub fn encode_loss_cmd(w: &[f64], buf: &mut Vec<u8>) -> Result<()> {
    begin_frame(buf);
    buf.push(CMD_LOSS);
    put_vec(buf, w);
    end_frame(buf)
}

/// [`Command::DaneSolve`] frame straight from the payload slices.
pub fn encode_dane_solve_cmd(
    w_prev: &[f64],
    g: &[f64],
    eta: f64,
    mu: f64,
    buf: &mut Vec<u8>,
) -> Result<()> {
    begin_frame(buf);
    buf.push(CMD_DANE_SOLVE);
    put_vec(buf, w_prev);
    put_vec(buf, g);
    put_f64(buf, eta);
    put_f64(buf, mu);
    end_frame(buf)
}

/// Append one command's tag + payload (no frame header). `envelope`
/// permits the `For` wrapper at this level; it is cleared for the nested
/// command so envelopes (and, by the same guard, setup frames) cannot
/// nest.
fn put_command_body(cmd: &Command, buf: &mut Vec<u8>, envelope: bool) -> Result<()> {
    match cmd {
        Command::Init(p) => {
            buf.push(CMD_INIT);
            put_u64(buf, p.worker_id as u64);
            put_str(buf, &p.loss_name);
            put_f64(buf, p.lambda);
            match p.gram_threads {
                None => buf.push(0),
                Some(t) => {
                    buf.push(1);
                    put_u64(buf, t as u64);
                }
            }
            put_shard(buf, &p.shard);
        }
        Command::InitRef(p) => {
            buf.push(CMD_INIT_REF);
            put_u64(buf, p.worker_id as u64);
            put_str(buf, &p.loss_name);
            put_f64(buf, p.lambda);
            match p.gram_threads {
                None => buf.push(0),
                Some(t) => {
                    buf.push(1);
                    put_u64(buf, t as u64);
                }
            }
            put_str(buf, &p.path);
            put_u64(buf, p.dim as u64);
            put_u64(buf, p.n as u64);
            put_u64(buf, p.machines as u64);
            put_u64(buf, p.shard_seed);
        }
        Command::GradLoss { w, out: _ } => {
            buf.push(CMD_GRAD_LOSS);
            put_vec(buf, w);
        }
        Command::Loss { w } => {
            buf.push(CMD_LOSS);
            put_vec(buf, w);
        }
        Command::DaneSolve { w_prev, g, eta, mu, out: _ } => {
            buf.push(CMD_DANE_SOLVE);
            put_vec(buf, w_prev);
            put_vec(buf, g);
            put_f64(buf, *eta);
            put_f64(buf, *mu);
        }
        Command::Prox { v, rho } => {
            buf.push(CMD_PROX);
            put_vec(buf, v);
            put_f64(buf, *rho);
        }
        Command::Erm { subsample } => {
            buf.push(CMD_ERM);
            match subsample {
                None => buf.push(0),
                Some((r, seed)) => {
                    buf.push(1);
                    put_f64(buf, *r);
                    put_u64(buf, *seed);
                }
            }
        }
        Command::RowSq => buf.push(CMD_ROW_SQ),
        Command::Peers(p) => {
            buf.push(CMD_PEERS);
            put_u64(buf, p.children.len() as u64);
            for c in &p.children {
                put_u64(buf, c.rank as u64);
                put_str(buf, &c.addr);
                put_u64(buf, c.ranks.len() as u64);
                for &r in &c.ranks {
                    put_u64(buf, r as u64);
                }
            }
            buf.push(u8::from(p.expect_parent));
        }
        Command::ProxAll { targets, rho } => {
            buf.push(CMD_PROX_ALL);
            put_u64(buf, targets.len() as u64);
            for t in targets {
                put_vec(buf, t);
            }
            put_f64(buf, *rho);
        }
        Command::For { rank, inner } => {
            if !envelope
                || matches!(
                    **inner,
                    Command::For { .. }
                        | Command::Init(_)
                        | Command::InitRef(_)
                        | Command::Peers(_)
                )
            {
                return Err(Error::Config(
                    "wire: For may only wrap a top-level compute command".into(),
                ));
            }
            buf.push(CMD_FOR);
            put_u64(buf, *rank as u64);
            put_command_body(inner, buf, false)?;
        }
        Command::CompressedVec(p) => {
            buf.push(CMD_COMPRESSED_VEC);
            put_compressed_cmd(p, buf)?;
        }
    }
    Ok(())
}

/// Append a compressed command payload (tag already written).
fn put_compressed_cmd(p: &compress::CompressedCmd, buf: &mut Vec<u8>) -> Result<()> {
    if p.vecs.len() != p.op.nvecs() {
        return Err(Error::Config(format!(
            "wire: compressed op carries {} vectors (expected {})",
            p.vecs.len(),
            p.op.nvecs()
        )));
    }
    buf.push(match p.op {
        CompressedOp::GradLoss => OP_GRAD_LOSS,
        CompressedOp::DaneSolve => OP_DANE_SOLVE,
    });
    put_f64(buf, p.eta);
    put_f64(buf, p.mu);
    let (codec_id, param) = codec_wire(p.spec.codec);
    buf.push(codec_id);
    put_u32(buf, param);
    buf.push(u8::from(p.spec.error_feedback));
    put_u64(buf, p.spec.seed);
    buf.push(p.vecs.len() as u8);
    for v in &p.vecs {
        put_coded_vec(v, buf);
    }
    Ok(())
}

/// Wire id + parameter for a codec choice.
fn codec_wire(c: Codec) -> (u8, u32) {
    match c {
        Codec::F32 => (CODEC_F32, 0),
        Codec::TopK { k } => (CODEC_TOPK, k.min(u32::MAX as usize) as u32),
        Codec::Quant { bits } => (CODEC_QUANT, u32::from(bits)),
    }
}

/// Append one compressed vector, self-describing (its codec byte first).
/// The byte count written here is exactly `CodedVec::wire_len()`; a test
/// below pins the two together.
fn put_coded_vec(v: &CodedVec, buf: &mut Vec<u8>) {
    match v {
        CodedVec::F32 { data } => {
            buf.push(CODEC_F32);
            put_u64(buf, data.len() as u64);
            for &x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        CodedVec::TopK { dim, idx, val } => {
            buf.push(CODEC_TOPK);
            put_u64(buf, *dim as u64);
            put_u64(buf, idx.len() as u64);
            for &i in idx {
                put_u32(buf, i);
            }
            put_f64s(buf, val);
        }
        CodedVec::Quant { dim, norm, bits, packed } => {
            buf.push(CODEC_QUANT);
            put_u64(buf, *dim as u64);
            put_f64(buf, *norm);
            buf.push(*bits);
            buf.extend_from_slice(packed);
        }
    }
}

/// Encode a full reply frame (length prefix included) into `buf`; same
/// oversize-body contract as [`encode_command`].
pub fn encode_reply(rep: &Reply, buf: &mut Vec<u8>) -> Result<()> {
    begin_frame(buf);
    match rep {
        Reply::Vec(v) => {
            buf.push(REP_VEC);
            put_vec(buf, v);
        }
        Reply::Scalar(x) => {
            buf.push(REP_SCALAR);
            put_f64(buf, *x);
        }
        Reply::VecScalar(v, x) => {
            buf.push(REP_VEC_SCALAR);
            put_vec(buf, v);
            put_f64(buf, *x);
        }
        Reply::VecPair(full, sub) => {
            buf.push(REP_VEC_PAIR);
            put_vec(buf, full);
            match sub {
                None => buf.push(0),
                Some(s) => {
                    buf.push(1);
                    put_vec(buf, s);
                }
            }
        }
        Reply::Err(msg) => {
            buf.push(REP_ERR);
            put_str(buf, msg);
        }
        Reply::CompressedVec(p) => {
            buf.push(REP_COMPRESSED_VEC);
            match p.loss {
                None => buf.push(0),
                Some(l) => {
                    buf.push(1);
                    put_f64(buf, l);
                }
            }
            put_coded_vec(&p.vec, buf);
        }
    }
    end_frame(buf)
}

fn begin_frame(buf: &mut Vec<u8>) {
    buf.clear();
    buf.extend_from_slice(&[0, 0, 0, 0]); // length prefix, patched below
    buf.push(WIRE_VERSION);
}

/// Patch the length prefix; rejects bodies the receive side would
/// refuse (and, past u32::MAX, ones whose prefix would silently wrap).
fn end_frame(buf: &mut Vec<u8>) -> Result<()> {
    let body = buf.len() - 4;
    if body > MAX_FRAME_LEN {
        return Err(Error::Config(format!(
            "wire: frame body {body} bytes exceeds cap {MAX_FRAME_LEN} — \
             shard or payload too large for one frame"
        )));
    }
    buf[..4].copy_from_slice(&(body as u32).to_le_bytes());
    Ok(())
}

fn put_f64(buf: &mut Vec<u8>, x: f64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

/// Append raw f64 LE bit patterns, no count prefix — the one write loop
/// shared by every vector-bearing frame (counted vectors, top-k values,
/// shard payloads).
fn put_f64s(buf: &mut Vec<u8>, v: &[f64]) {
    for &x in v {
        put_f64(buf, x);
    }
}

fn put_vec(buf: &mut Vec<u8>, v: &[f64]) {
    put_u64(buf, v.len() as u64);
    put_f64s(buf, v);
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

fn put_shard(buf: &mut Vec<u8>, shard: &Shard) {
    match &shard.x {
        DataMatrix::Dense(m) => {
            buf.push(MAT_DENSE);
            put_u64(buf, m.rows() as u64);
            put_u64(buf, m.cols() as u64);
            put_f64s(buf, m.data());
        }
        DataMatrix::Sparse(s) => {
            buf.push(MAT_SPARSE);
            put_u64(buf, s.rows() as u64);
            put_u64(buf, s.cols() as u64);
            put_u64(buf, s.nnz() as u64);
            for i in 0..s.rows() {
                let (idx, vals) = s.row(i);
                put_u64(buf, idx.len() as u64);
                for &j in idx {
                    put_u32(buf, j);
                }
                put_f64s(buf, vals);
            }
        }
    }
    put_vec(buf, &shard.y);
    put_u64(buf, shard.n_effective() as u64);
}

// ---------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------

/// Bounds-checked cursor over a frame body; every accessor fails with a
/// `Config` error instead of panicking or over-allocating.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Self {
        Cur { b, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::Config(format!(
                "wire: truncated frame (need {n} more bytes, have {})",
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64> {
        let s = self.take(8)?;
        let mut b = [0u8; 8];
        b.copy_from_slice(s);
        Ok(f64::from_le_bytes(b))
    }

    /// A `u64` count that must describe `elem_size`-byte elements still
    /// present in the frame — the guard that makes hostile counts cost
    /// nothing (no allocation ever exceeds the received bytes).
    fn count(&mut self, elem_size: usize, what: &str) -> Result<usize> {
        let n = self.u64()?;
        let need = (n as u128) * elem_size as u128;
        if need > self.remaining() as u128 {
            return Err(Error::Config(format!(
                "wire: {what} count {n} exceeds frame ({} bytes left)",
                self.remaining()
            )));
        }
        Ok(n as usize)
    }

    /// Append `n` f64 values onto `out` — the one read loop shared by
    /// every vector-bearing frame. Callers validate `n` via [`Cur::count`]
    /// first, so the reserve is bounded by received bytes. Takes the
    /// whole `8n`-byte region in one bounds check, then converts through
    /// `chunks_exact(8)` — the per-element cursor arithmetic of a naive
    /// `f64()` loop is what made decode ~3x slower than encode
    /// (BENCH_wire.json); `wire_micro`'s decode entry pins the fix.
    fn take_f64s(&mut self, n: usize, out: &mut Vec<f64>) -> Result<()> {
        let bytes = self.take(n.checked_mul(8).ok_or_else(|| {
            Error::Config(format!("wire: vector count {n} overflows byte size"))
        })?)?;
        out.reserve(n);
        for c in bytes.chunks_exact(8) {
            out.push(f64::from_le_bytes([
                c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
            ]));
        }
        Ok(())
    }

    fn vec_f64(&mut self) -> Result<Vec<f64>> {
        let n = self.count(8, "vector")?;
        let mut v = Vec::new();
        self.take_f64s(n, &mut v)?;
        Ok(v)
    }

    fn string(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let s = self.take(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| Error::Config("wire: string is not UTF-8".into()))
    }

    /// Reject trailing garbage: a well-formed frame is consumed exactly.
    fn done(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::Config(format!(
                "wire: {} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn check_version(cur: &mut Cur) -> Result<u8> {
    let v = cur.u8()?;
    if v != WIRE_VERSION {
        return Err(Error::Config(format!(
            "wire: version {v} (expected {WIRE_VERSION})"
        )));
    }
    cur.u8()
}

/// Decode a command frame body (the bytes after the length prefix).
pub fn decode_command(body: &[u8]) -> Result<Command> {
    let mut cur = Cur::new(body);
    let tag = check_version(&mut cur)?;
    let cmd = take_command(&mut cur, tag, true)?;
    cur.done()?;
    Ok(cmd)
}

/// Decode one command's payload given its already-read `tag`.
/// `envelope` permits `For` at this level only (no nesting).
fn take_command(cur: &mut Cur, tag: u8, envelope: bool) -> Result<Command> {
    let cmd = match tag {
        CMD_INIT => {
            let worker_id = cur.u64()? as usize;
            let loss_name = cur.string()?;
            let lambda = cur.f64()?;
            let gram_threads = match cur.u8()? {
                0 => None,
                1 => Some(cur.u64()? as usize),
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad gram_threads marker {b}"
                    )))
                }
            };
            let shard = take_shard(&mut cur)?;
            Command::Init(Box::new(InitPayload {
                worker_id,
                loss_name,
                lambda,
                gram_threads,
                shard,
            }))
        }
        CMD_INIT_REF => {
            let worker_id = cur.u64()? as usize;
            let loss_name = cur.string()?;
            let lambda = cur.f64()?;
            let gram_threads = match cur.u8()? {
                0 => None,
                1 => Some(cur.u64()? as usize),
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad gram_threads marker {b}"
                    )))
                }
            };
            let path = cur.string()?;
            let dim = cur.u64()? as usize;
            let n = cur.u64()? as usize;
            let machines = cur.u64()? as usize;
            let shard_seed = cur.u64()?;
            // Validate the sharding parameters here so the serve loop
            // can hand them straight to `shard_indices` (which asserts)
            // without a hostile frame ever reaching a panic.
            if machines == 0 || worker_id >= machines {
                return Err(Error::Config(format!(
                    "wire: init-ref rank {worker_id} out of range (m={machines})"
                )));
            }
            if n < machines {
                return Err(Error::Config(format!(
                    "wire: init-ref has fewer rows ({n}) than machines ({machines})"
                )));
            }
            if dim == 0 {
                return Err(Error::Config(
                    "wire: init-ref dim must be explicit (nonzero)".into(),
                ));
            }
            Command::InitRef(Box::new(InitRefPayload {
                worker_id,
                loss_name,
                lambda,
                gram_threads,
                path,
                dim,
                n,
                machines,
                shard_seed,
            }))
        }
        CMD_GRAD_LOSS => Command::GradLoss {
            w: Arc::new(cur.vec_f64()?),
            out: Vec::new(),
        },
        CMD_LOSS => Command::Loss { w: Arc::new(cur.vec_f64()?) },
        CMD_DANE_SOLVE => {
            let w_prev = Arc::new(cur.vec_f64()?);
            let g = Arc::new(cur.vec_f64()?);
            let eta = cur.f64()?;
            let mu = cur.f64()?;
            Command::DaneSolve { w_prev, g, eta, mu, out: Vec::new() }
        }
        CMD_PROX => {
            let v = cur.vec_f64()?;
            let rho = cur.f64()?;
            Command::Prox { v, rho }
        }
        CMD_ERM => {
            let subsample = match cur.u8()? {
                0 => None,
                1 => Some((cur.f64()?, cur.u64()?)),
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad subsample marker {b}"
                    )))
                }
            };
            Command::Erm { subsample }
        }
        CMD_ROW_SQ => Command::RowSq,
        CMD_PEERS => {
            // each child carries at least rank(8) + addr len(4) +
            // ranks count(8) = 20 bytes
            let n = cur.count(20, "peers children")?;
            let mut children = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = cur.u64()? as usize;
                let addr = cur.string()?;
                let k = cur.count(8, "peer subtree")?;
                let mut ranks = Vec::with_capacity(k);
                for _ in 0..k {
                    ranks.push(cur.u64()? as usize);
                }
                if ranks.first() != Some(&rank) {
                    return Err(Error::Config(format!(
                        "wire: peer subtree must start at its root \
                         (child {rank}, got {:?})",
                        ranks.first()
                    )));
                }
                children.push(PeerChild { rank, addr, ranks });
            }
            let expect_parent = match cur.u8()? {
                0 => false,
                1 => true,
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad expect_parent marker {b}"
                    )))
                }
            };
            Command::Peers(Box::new(PeersPayload { children, expect_parent }))
        }
        CMD_PROX_ALL => {
            // each target carries at least its own u64 length
            let n = cur.count(8, "prox targets")?;
            let mut targets = Vec::with_capacity(n);
            for _ in 0..n {
                targets.push(cur.vec_f64()?);
            }
            let rho = cur.f64()?;
            Command::ProxAll { targets, rho }
        }
        CMD_FOR if envelope => {
            let rank = cur.u64()? as usize;
            let inner_tag = cur.u8()?;
            if matches!(inner_tag, CMD_INIT | CMD_INIT_REF | CMD_PEERS) {
                return Err(Error::Config(
                    "wire: For may only wrap a compute command".into(),
                ));
            }
            let inner = take_command(cur, inner_tag, false)?;
            Command::For { rank, inner: Box::new(inner) }
        }
        CMD_FOR => {
            return Err(Error::Config("wire: nested For envelope".into()))
        }
        CMD_COMPRESSED_VEC => {
            let op = match cur.u8()? {
                OP_GRAD_LOSS => CompressedOp::GradLoss,
                OP_DANE_SOLVE => CompressedOp::DaneSolve,
                b => {
                    return Err(Error::Config(format!(
                        "wire: unknown compressed op {b}"
                    )))
                }
            };
            let eta = cur.f64()?;
            let mu = cur.f64()?;
            let codec_id = cur.u8()?;
            let param = cur.u32()?;
            let codec = match codec_id {
                CODEC_F32 if param == 0 => Codec::F32,
                CODEC_TOPK if param >= 1 => Codec::TopK { k: param as usize },
                CODEC_QUANT if (1..=8).contains(&param) => {
                    Codec::Quant { bits: param as u8 }
                }
                _ => {
                    return Err(Error::Config(format!(
                        "wire: bad codec spec (id {codec_id}, param {param})"
                    )))
                }
            };
            let error_feedback = match cur.u8()? {
                0 => false,
                1 => true,
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad error_feedback marker {b}"
                    )))
                }
            };
            let seed = cur.u64()?;
            let nvecs = cur.u8()? as usize;
            if nvecs != op.nvecs() {
                return Err(Error::Config(format!(
                    "wire: compressed op carries {nvecs} vectors (expected {})",
                    op.nvecs()
                )));
            }
            let mut vecs = Vec::with_capacity(nvecs);
            for _ in 0..nvecs {
                vecs.push(take_coded_vec(cur)?);
            }
            Command::CompressedVec(Arc::new(compress::CompressedCmd {
                op,
                eta,
                mu,
                spec: ReplySpec { codec, error_feedback, seed },
                vecs,
            }))
        }
        t => return Err(Error::Config(format!("wire: unknown command tag {t:#x}"))),
    };
    Ok(cmd)
}

/// Decode one self-described compressed vector. Total: hostile counts,
/// out-of-range or unsorted top-k indices, non-finite top-k values /
/// quant norms, and bad bit widths all come back as `Err` before any
/// attacker-sized allocation (reconstruction to `dim` only happens after
/// the receiver checks `dim()` against its own problem dimension).
fn take_coded_vec(cur: &mut Cur) -> Result<CodedVec> {
    match cur.u8()? {
        CODEC_F32 => {
            let n = cur.count(4, "f32 vector")?;
            let mut data = Vec::with_capacity(n);
            for _ in 0..n {
                let s = cur.take(4)?;
                data.push(f32::from_le_bytes([s[0], s[1], s[2], s[3]]));
            }
            Ok(CodedVec::F32 { data })
        }
        CODEC_TOPK => {
            let dim = cur.u64()?;
            if dim > (MAX_FRAME_LEN / 8) as u64 {
                return Err(Error::Config(format!(
                    "wire: top-k dim {dim} exceeds cap"
                )));
            }
            let dim = dim as usize;
            let k = cur.count(12, "top-k entries")?;
            if k > dim {
                return Err(Error::Config(format!(
                    "wire: top-k keeps {k} of {dim} entries"
                )));
            }
            let mut idx: Vec<u32> = Vec::with_capacity(k);
            for _ in 0..k {
                let i = cur.u32()?;
                if i as usize >= dim || idx.last().is_some_and(|&p| p >= i) {
                    return Err(Error::Config(format!(
                        "wire: top-k index {i} out of range or order (dim {dim})"
                    )));
                }
                idx.push(i);
            }
            let mut val = Vec::new();
            cur.take_f64s(k, &mut val)?;
            if let Some(bad) = val.iter().find(|v| !v.is_finite()) {
                return Err(Error::Config(format!(
                    "wire: top-k value {bad} is not finite"
                )));
            }
            Ok(CodedVec::TopK { dim, idx, val })
        }
        CODEC_QUANT => {
            let dim = cur.u64()?;
            let norm = cur.f64()?;
            if !norm.is_finite() || norm < 0.0 {
                return Err(Error::Config(format!(
                    "wire: quant norm {norm} is not a finite nonnegative"
                )));
            }
            let bits = cur.u8()?;
            if !(1..=8).contains(&bits) {
                return Err(Error::Config(format!(
                    "wire: quant bits {bits} outside 1..=8"
                )));
            }
            let need = compress::quant_packed_len(dim, bits);
            if need > cur.remaining() as u128 {
                return Err(Error::Config(format!(
                    "wire: quant dim {dim} exceeds frame"
                )));
            }
            let packed = cur.take(need as usize)?.to_vec();
            Ok(CodedVec::Quant { dim: dim as usize, norm, bits, packed })
        }
        c => Err(Error::Config(format!("wire: unknown codec id {c}"))),
    }
}

/// Decode a reply frame body (the bytes after the length prefix).
pub fn decode_reply(body: &[u8]) -> Result<Reply> {
    let mut cur = Cur::new(body);
    let tag = check_version(&mut cur)?;
    let rep = match tag {
        REP_VEC => Reply::Vec(cur.vec_f64()?),
        REP_SCALAR => Reply::Scalar(cur.f64()?),
        REP_VEC_SCALAR => {
            let v = cur.vec_f64()?;
            let x = cur.f64()?;
            Reply::VecScalar(v, x)
        }
        REP_VEC_PAIR => {
            let full = cur.vec_f64()?;
            let sub = match cur.u8()? {
                0 => None,
                1 => Some(cur.vec_f64()?),
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad subsample marker {b}"
                    )))
                }
            };
            Reply::VecPair(full, sub)
        }
        REP_ERR => Reply::Err(cur.string()?),
        REP_COMPRESSED_VEC => {
            let loss = match cur.u8()? {
                0 => None,
                1 => Some(cur.f64()?),
                b => {
                    return Err(Error::Config(format!(
                        "wire: bad loss marker {b}"
                    )))
                }
            };
            let vec = take_coded_vec(&mut cur)?;
            Reply::CompressedVec(Box::new(compress::CompressedReply { loss, vec }))
        }
        t => return Err(Error::Config(format!("wire: unknown reply tag {t:#x}"))),
    };
    cur.done()?;
    Ok(rep)
}

/// Decode a shard, validating every invariant the `CsrMatrix::new` /
/// `Shard::with_padding` constructors would otherwise assert — malformed
/// frames must come back as `Err`, never a panic.
fn take_shard(cur: &mut Cur) -> Result<Shard> {
    let x = match cur.u8()? {
        MAT_DENSE => {
            let rows = cur.u64()? as usize;
            let cols = cur.u64()? as usize;
            let cells = (rows as u128) * cols as u128;
            if cells * 8 > cur.remaining() as u128 {
                return Err(Error::Config(format!(
                    "wire: dense {rows}x{cols} exceeds frame"
                )));
            }
            let mut data = Vec::new();
            cur.take_f64s(cells as usize, &mut data)?;
            DataMatrix::Dense(DenseMatrix::from_vec(rows, cols, data))
        }
        MAT_SPARSE => {
            let rows = cur.u64()?;
            let cols = cur.u64()? as usize;
            let nnz = cur.u64()?;
            // every row carries at least its u64 nnz count, so a frame
            // can only describe remaining/8 rows — reject hostile row
            // counts before sizing indptr
            if (rows as u128) * 8 > cur.remaining() as u128 {
                return Err(Error::Config(format!(
                    "wire: sparse row count {rows} exceeds frame"
                )));
            }
            let rows = rows as usize;
            if (nnz as u128) * 12 > cur.remaining() as u128 {
                return Err(Error::Config(format!(
                    "wire: sparse nnz {nnz} exceeds frame"
                )));
            }
            let nnz = nnz as usize;
            let mut indptr = Vec::with_capacity(rows + 1);
            let mut indices: Vec<u32> = Vec::with_capacity(nnz);
            let mut data: Vec<f64> = Vec::with_capacity(nnz);
            indptr.push(0usize);
            for _ in 0..rows {
                let k = cur.count(12, "sparse row")?;
                for _ in 0..k {
                    let j = cur.u32()?;
                    if j as usize >= cols {
                        return Err(Error::Config(format!(
                            "wire: sparse column {j} out of range (d={cols})"
                        )));
                    }
                    indices.push(j);
                }
                cur.take_f64s(k, &mut data)?;
                indptr.push(indices.len());
            }
            if indices.len() != nnz {
                return Err(Error::Config(format!(
                    "wire: sparse nnz mismatch ({} vs {nnz})",
                    indices.len()
                )));
            }
            DataMatrix::Sparse(CsrMatrix::new(rows, cols, indptr, indices, data))
        }
        k => return Err(Error::Config(format!("wire: unknown matrix kind {k}"))),
    };
    let y = cur.vec_f64()?;
    if y.len() != x.rows() {
        return Err(Error::Config(format!(
            "wire: shard y length {} != rows {}",
            y.len(),
            x.rows()
        )));
    }
    let n_effective = cur.u64()? as usize;
    if n_effective > x.rows() {
        return Err(Error::Config(format!(
            "wire: n_effective {n_effective} exceeds rows {}",
            x.rows()
        )));
    }
    Ok(Shard::with_padding(x, y, n_effective))
}

// ---------------------------------------------------------------------
// framed I/O
// ---------------------------------------------------------------------

/// Read one frame body into `body` (resized in place and reused).
/// Returns `Ok(None)` on a clean disconnect *at a frame boundary* (the
/// peer hung up between rounds), `Ok(Some(total_bytes))` — length prefix
/// included — on success, and `Err` on mid-frame EOF, an oversize length
/// prefix, or any transport error.
///
/// The body buffer retains its capacity across frames: a frame no larger
/// than the previous one is read with zero allocation and zero
/// re-zeroing (the steady state of a round loop, where every frame of a
/// collective has the same size). A larger frame grows the buffer
/// geometrically from [`READ_SEED`], resizing only once the bytes
/// already received fill it — so a hostile length prefix costs at most
/// ~2x the bytes the peer actually sent, never an attacker-sized
/// up-front allocation.
pub fn read_frame<R: Read>(r: &mut R, body: &mut Vec<u8>) -> Result<Option<usize>> {
    let mut prefix = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut prefix[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None); // clean EOF between frames
            }
            return Err(Error::Runtime(
                "wire: connection closed mid-frame".into(),
            ));
        }
        got += n;
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > MAX_FRAME_LEN {
        return Err(Error::Config(format!(
            "wire: frame length {len} exceeds cap {MAX_FRAME_LEN}"
        )));
    }
    if len < 2 {
        return Err(Error::Config(format!(
            "wire: frame length {len} below header size"
        )));
    }
    // `body.len() <= len` from here on, so a read can never swallow
    // bytes of the next frame on the stream.
    if body.len() > len {
        body.truncate(len);
    }
    let mut filled = 0;
    while filled < len {
        if filled == body.len() {
            // Grow toward `len` only as received bytes fill the buffer;
            // `resize` zeroes just the newly exposed region.
            let next = body.len().saturating_mul(2).clamp(READ_SEED.min(len), len);
            body.resize(next, 0);
        }
        let n = r.read(&mut body[filled..])?;
        if n == 0 {
            return Err(Error::Runtime(
                "wire: connection closed mid-frame".into(),
            ));
        }
        filled += n;
    }
    Ok(Some(4 + len))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_reply(r: &Reply) -> Reply {
        let mut buf = Vec::new();
        encode_reply(r, &mut buf).unwrap();
        decode_reply(&buf[4..]).unwrap()
    }

    #[test]
    fn reply_scalar_roundtrips() {
        match roundtrip_reply(&Reply::Scalar(-3.25)) {
            Reply::Scalar(x) => assert_eq!(x, -3.25),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn command_dane_solve_roundtrips() {
        let cmd = Command::DaneSolve {
            w_prev: Arc::new(vec![1.0, f64::NAN, -0.0]),
            g: Arc::new(vec![f64::INFINITY]),
            eta: 0.5,
            mu: 1e-9,
            out: vec![9.0; 4], // buffer loan: must NOT survive the wire
        };
        let mut buf = Vec::new();
        encode_command(&cmd, &mut buf).unwrap();
        match decode_command(&buf[4..]).unwrap() {
            Command::DaneSolve { w_prev, g, eta, mu, out } => {
                assert_eq!(w_prev.len(), 3);
                assert_eq!(w_prev[1].to_bits(), f64::NAN.to_bits());
                assert_eq!(w_prev[2].to_bits(), (-0.0f64).to_bits());
                assert_eq!(g[0], f64::INFINITY);
                assert_eq!(eta, 0.5);
                assert_eq!(mu, 1e-9);
                assert!(out.is_empty(), "out is transport state, not wire content");
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn bad_version_and_tag_rejected() {
        let mut buf = Vec::new();
        encode_reply(&Reply::Scalar(1.0), &mut buf).unwrap();
        let mut body = buf[4..].to_vec();
        body[0] = 99; // version
        assert!(decode_reply(&body).is_err());
        let mut body = buf[4..].to_vec();
        body[1] = 0x7f; // tag
        assert!(decode_reply(&body).is_err());
    }

    #[test]
    fn truncation_and_trailing_bytes_rejected() {
        let mut buf = Vec::new();
        encode_reply(&Reply::Vec(vec![1.0, 2.0, 3.0]), &mut buf).unwrap();
        let body = &buf[4..];
        for cut in 0..body.len() {
            assert!(decode_reply(&body[..cut]).is_err(), "cut at {cut}");
        }
        let mut long = body.to_vec();
        long.push(0);
        assert!(decode_reply(&long).is_err(), "trailing byte accepted");
    }

    #[test]
    fn oversize_length_prefix_rejected() {
        let mut frame = ((MAX_FRAME_LEN + 1) as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&[0; 16]);
        let mut body = Vec::new();
        assert!(read_frame(&mut frame.as_slice(), &mut body).is_err());
    }

    #[test]
    fn peers_and_prox_all_roundtrip() {
        let p = PeersPayload {
            children: vec![
                PeerChild {
                    rank: 2,
                    addr: "127.0.0.1:4471".into(),
                    ranks: vec![2, 6],
                },
                PeerChild { rank: 4, addr: "10.0.0.3:9".into(), ranks: vec![4] },
            ],
            expect_parent: true,
        };
        let mut buf = Vec::new();
        encode_command(&Command::Peers(Box::new(p.clone())), &mut buf).unwrap();
        match decode_command(&buf[4..]).unwrap() {
            Command::Peers(q) => assert_eq!(*q, p),
            _ => panic!("wrong variant"),
        }

        let targets = vec![vec![1.0, f64::NAN], vec![-0.0, 2.0]];
        encode_command(&Command::ProxAll { targets: targets.clone(), rho: 0.3 }, &mut buf)
            .unwrap();
        match decode_command(&buf[4..]).unwrap() {
            Command::ProxAll { targets: t, rho } => {
                assert_eq!(rho, 0.3);
                assert_eq!(t.len(), 2);
                assert_eq!(t[0][1].to_bits(), f64::NAN.to_bits());
                assert_eq!(t[1], vec![-0.0, 2.0]);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn for_envelope_roundtrips_and_rejects_nesting() {
        let inner = Command::DaneSolve {
            w_prev: Arc::new(vec![1.0]),
            g: Arc::new(vec![2.0]),
            eta: 1.0,
            mu: 0.0,
            out: vec![9.0], // loaned buffer must not survive the wire
        };
        let cmd = Command::For { rank: 5, inner: Box::new(inner) };
        let mut buf = Vec::new();
        encode_command(&cmd, &mut buf).unwrap();
        match decode_command(&buf[4..]).unwrap() {
            Command::For { rank, inner } => {
                assert_eq!(rank, 5);
                match *inner {
                    Command::DaneSolve { ref w_prev, ref out, .. } => {
                        assert_eq!(**w_prev, vec![1.0]);
                        assert!(out.is_empty());
                    }
                    _ => panic!("inner variant changed"),
                }
            }
            _ => panic!("wrong variant"),
        }

        // nesting an envelope (or a setup frame) inside For is rejected
        // on the encode side...
        let nested = Command::For {
            rank: 0,
            inner: Box::new(Command::For { rank: 1, inner: Box::new(Command::RowSq) }),
        };
        assert!(encode_command(&nested, &mut buf).is_err());
        let setup = Command::For {
            rank: 0,
            inner: Box::new(Command::Peers(Box::new(PeersPayload {
                children: Vec::new(),
                expect_parent: false,
            }))),
        };
        assert!(encode_command(&setup, &mut buf).is_err());
        // ...and a handcrafted nested frame is rejected on decode.
        let mut body = vec![WIRE_VERSION, 0x0a];
        body.extend_from_slice(&0u64.to_le_bytes());
        body.push(0x0a); // inner tag: For again
        body.extend_from_slice(&1u64.to_le_bytes());
        body.push(0x07); // RowSq
        assert!(decode_command(&body).is_err());
    }

    fn init_ref() -> InitRefPayload {
        InitRefPayload {
            worker_id: 2,
            loss_name: "ridge".into(),
            lambda: 0.01,
            gram_threads: Some(3),
            path: "/data/rcv1.svm".into(),
            dim: 47_236,
            n: 677_399,
            machines: 8,
            shard_seed: 12,
        }
    }

    #[test]
    fn init_ref_roundtrips_and_stays_small() {
        let p = init_ref();
        let mut buf = Vec::new();
        encode_command(&Command::InitRef(Box::new(p.clone())), &mut buf).unwrap();
        // O(1) in the dataset size: metadata only
        assert!(buf.len() < 256, "InitRef frame ballooned to {} bytes", buf.len());
        match decode_command(&buf[4..]).unwrap() {
            Command::InitRef(q) => assert_eq!(*q, p),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn init_ref_rejects_hostile_sharding_params() {
        let mut buf = Vec::new();
        let cases: [(fn(&mut InitRefPayload), &str); 4] = [
            (|p| p.machines = 0, "rank"),
            (|p| p.worker_id = 8, "rank"),
            (|p| p.n = 7, "fewer rows"),
            (|p| p.dim = 0, "dim"),
        ];
        for (fix, expect) in cases {
            let mut p = init_ref();
            fix(&mut p);
            encode_command(&Command::InitRef(Box::new(p)), &mut buf).unwrap();
            let err = decode_command(&buf[4..]).unwrap_err();
            assert!(err.to_string().contains(expect), "{err}");
        }
    }

    #[test]
    fn init_ref_cannot_ride_a_for_envelope() {
        let mut buf = Vec::new();
        let cmd = Command::For {
            rank: 0,
            inner: Box::new(Command::InitRef(Box::new(init_ref()))),
        };
        assert!(encode_command(&cmd, &mut buf).is_err());
        // and a handcrafted For{InitRef} frame dies on decode
        let mut body = vec![WIRE_VERSION, 0x0a];
        body.extend_from_slice(&0u64.to_le_bytes());
        body.push(0x0b); // inner tag: InitRef
        assert!(decode_command(&body).is_err());
    }

    #[test]
    fn relay_copy_shares_arcs_and_drops_loans() {
        let w = Arc::new(vec![1.0, 2.0]);
        let cmd = Command::GradLoss { w: w.clone(), out: vec![0.0; 2] };
        match cmd.relay_copy() {
            Command::GradLoss { w: w2, out } => {
                assert!(Arc::ptr_eq(&w, &w2), "broadcast payload must be shared");
                assert!(out.is_empty(), "loaned buffer must not be copied");
            }
            _ => panic!("wrong variant"),
        }
    }

    fn compressed_cmd(codec: Codec) -> Command {
        let spec = ReplySpec { codec, error_feedback: true, seed: 42 };
        let w = vec![0.5, -3.0, 0.0, 2.0, -0.25];
        let g = vec![1.0, 0.0, -1.0, 0.5, 4.0];
        let mut rng = crate::util::rng::Rng64::seed_from_u64(9);
        Command::CompressedVec(Arc::new(compress::CompressedCmd {
            op: CompressedOp::DaneSolve,
            eta: 1.0,
            mu: 0.125,
            spec,
            vecs: vec![
                CodedVec::encode(codec, &w, &mut rng),
                CodedVec::encode(codec, &g, &mut rng),
            ],
        }))
    }

    #[test]
    fn compressed_cmd_roundtrips_every_codec() {
        for codec in [Codec::F32, Codec::TopK { k: 2 }, Codec::Quant { bits: 4 }] {
            let cmd = compressed_cmd(codec);
            let mut buf = Vec::new();
            encode_command(&cmd, &mut buf).unwrap();
            match (decode_command(&buf[4..]).unwrap(), &cmd) {
                (Command::CompressedVec(got), Command::CompressedVec(sent)) => {
                    assert_eq!(&*got, &**sent, "codec {codec:?}");
                }
                _ => panic!("wrong variant"),
            }
        }
    }

    #[test]
    fn compressed_reply_roundtrips_and_frame_len_is_exact() {
        let mut rng = crate::util::rng::Rng64::seed_from_u64(4);
        let x = vec![1.0, -2.0, 0.0, 8.5, -0.5, 3.25, 0.0];
        for codec in [Codec::F32, Codec::TopK { k: 3 }, Codec::Quant { bits: 3 }] {
            for loss in [None, Some(0.75)] {
                let rep = compress::CompressedReply {
                    loss,
                    vec: CodedVec::encode(codec, &x, &mut rng),
                };
                let expect = rep.frame_len();
                let rep = Reply::CompressedVec(Box::new(rep));
                let mut buf = Vec::new();
                encode_reply(&rep, &mut buf).unwrap();
                assert_eq!(
                    buf.len() as u64,
                    expect,
                    "frame_len must match the real encoder ({codec:?})"
                );
                match (decode_reply(&buf[4..]).unwrap(), rep) {
                    (Reply::CompressedVec(got), Reply::CompressedVec(sent)) => {
                        assert_eq!(got, sent);
                    }
                    _ => panic!("wrong variant"),
                }
            }
        }
    }

    #[test]
    fn raw_frame_len_helpers_match_real_encoders() {
        let d = 5;
        let w = Arc::new(vec![1.5; d]);
        let mut buf = Vec::new();
        let cmd = Command::GradLoss { w: w.clone(), out: Vec::new() };
        encode_command(&cmd, &mut buf).unwrap();
        assert_eq!(
            buf.len() as u64,
            compress::raw_cmd_frame_len(CompressedOp::GradLoss, d)
        );
        let cmd = Command::DaneSolve {
            w_prev: w.clone(),
            g: w.clone(),
            eta: 1.0,
            mu: 0.0,
            out: Vec::new(),
        };
        encode_command(&cmd, &mut buf).unwrap();
        assert_eq!(
            buf.len() as u64,
            compress::raw_cmd_frame_len(CompressedOp::DaneSolve, d)
        );
        encode_reply(&Reply::VecScalar(vec![0.0; d], 1.0), &mut buf).unwrap();
        assert_eq!(
            buf.len() as u64,
            compress::raw_reply_frame_len(CompressedOp::GradLoss, d)
        );
        encode_reply(&Reply::Vec(vec![0.0; d]), &mut buf).unwrap();
        assert_eq!(
            buf.len() as u64,
            compress::raw_reply_frame_len(CompressedOp::DaneSolve, d)
        );
    }

    #[test]
    fn read_frame_clean_eof_is_none() {
        let mut body = Vec::new();
        let mut empty: &[u8] = &[];
        assert_eq!(read_frame(&mut empty, &mut body).unwrap(), None);
        // mid-prefix EOF is an error, not a clean disconnect
        let mut partial: &[u8] = &[1u8, 0];
        assert!(read_frame(&mut partial, &mut body).is_err());
    }

    #[test]
    fn raw_slice_encoders_match_encode_command_bytes() {
        // the allocation-free TCP leader path must put byte-identical
        // frames on the wire — including NaN/-0.0 bit patterns
        let w = vec![1.5, f64::NAN, -0.0, 3.25, -2.0];
        let g = vec![0.5, f64::NEG_INFINITY, 7.0];
        let (mut a, mut b) = (Vec::new(), Vec::new());

        let cmd = Command::GradLoss { w: Arc::new(w.clone()), out: Vec::new() };
        encode_command(&cmd, &mut a).unwrap();
        encode_grad_loss_cmd(&w, &mut b).unwrap();
        assert_eq!(a, b, "GradLoss raw encoder diverged");

        encode_command(&Command::Loss { w: Arc::new(w.clone()) }, &mut a).unwrap();
        encode_loss_cmd(&w, &mut b).unwrap();
        assert_eq!(a, b, "Loss raw encoder diverged");

        let cmd = Command::DaneSolve {
            w_prev: Arc::new(w.clone()),
            g: Arc::new(g.clone()),
            eta: 0.75,
            mu: 1e-9,
            out: Vec::new(),
        };
        encode_command(&cmd, &mut a).unwrap();
        encode_dane_solve_cmd(&w, &g, 0.75, 1e-9, &mut b).unwrap();
        assert_eq!(a, b, "DaneSolve raw encoder diverged");
    }

    #[test]
    fn read_frame_retains_capacity_across_frames() {
        // big frame then small frame on one stream: the second read
        // must reuse the first frame's buffer (no shrink below the
        // retained capacity) and still hand back exactly its body
        let (mut f1, mut f2) = (Vec::new(), Vec::new());
        encode_reply(&Reply::Vec(vec![0.25; 100]), &mut f1).unwrap();
        encode_reply(&Reply::Scalar(7.0), &mut f2).unwrap();
        let mut stream = f1.clone();
        stream.extend_from_slice(&f2);
        let mut r = stream.as_slice();
        let mut body = Vec::new();
        assert_eq!(read_frame(&mut r, &mut body).unwrap(), Some(f1.len()));
        assert_eq!(body, f1[4..], "first body");
        let cap = body.capacity();
        assert!(cap >= f1.len() - 4);
        assert_eq!(read_frame(&mut r, &mut body).unwrap(), Some(f2.len()));
        assert_eq!(body, f2[4..], "second body");
        assert_eq!(body.capacity(), cap, "capacity must be retained");
        assert!(matches!(decode_reply(&body).unwrap(), Reply::Scalar(x) if x == 7.0));
        assert_eq!(read_frame(&mut r, &mut body).unwrap(), None);
    }

    #[test]
    fn hostile_length_prefix_costs_bounded_buffer() {
        // prefix claims the full 1 GiB cap but only 5 body bytes ever
        // arrive: mid-frame EOF error, with a buffer no larger than the
        // seed growth step — not an attacker-sized allocation
        let mut frame = (MAX_FRAME_LEN as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&[1, 2, 3, 4, 5]);
        let mut body = Vec::new();
        assert!(read_frame(&mut frame.as_slice(), &mut body).is_err());
        assert!(
            body.capacity() <= 2 * READ_SEED,
            "buffer grew to {} bytes for 5 hostile bytes",
            body.capacity()
        );
    }
}
