//! Transport-level collective topologies: how a round's fan-out and
//! fan-in are *executed*, as opposed to how they are *modeled*
//! ([`super::netmodel`]).
//!
//! The paper's premise is that communication rounds dominate wallclock;
//! `netmodel` quantifies that with alpha-beta costs per topology. This
//! module makes the topologies real: the concurrent engines
//! (`coordinator::threaded`, `coordinator::tcp`) select one of three
//! execution strategies through [`ExecTopology`] (config/CLI key
//! `topology`):
//!
//! * **`star-seq`** — the historical baseline: the leader writes and
//!   reads every worker sequentially, an O(m·B) critical path through
//!   the root. Kept selectable so `benches/wire_micro.rs` can measure
//!   what the other two strategies buy.
//! * **`star`** (default) — parallel star: one I/O actor per
//!   leader-adjacent connection (a socket-owning thread on `TcpCluster`;
//!   on `ThreadedCluster` the per-worker worker threads already play
//!   this role), so the m broadcast-writes and m gather-reads overlap
//!   instead of serializing on the leader thread.
//! * **`tree`** — binomial-tree relay: the leader talks only to its
//!   O(log m) direct children; interior workers forward command frames
//!   to their children and relay ordered reply bundles back up
//!   ([`TreePlan`]).
//!
//! ## The fixed-order reduction guarantee
//!
//! Whatever the topology, the *numerical reduction* is always performed
//! at the root, in worker-rank order, from buffered per-worker
//! contributions ([`RankGather`]) — the same discipline as the
//! deterministic `par_gram` kernel (fixed partials, fixed combine
//! order). Interior tree nodes aggregate *ordered bundles* of their
//! subtree's replies; they never combine floating-point values, because
//! a tree-shaped numeric combine would change summation associativity
//! and break the bit-exact serial ≡ threaded ≡ tcp trace parity the
//! test suite pins. Consequently traces are bit-identical across the
//! whole engine × topology matrix; only `modeled_seconds` (which
//! switches on the configured topology — like for like with the
//! execution strategy) and `wire_bytes` (transport-measured) differ.
//!
//! ## Tree shape
//!
//! The binomial broadcast tree over m workers + 1 leader, nodes
//! numbered 0..=m with the leader at node 0 and worker rank r at node
//! r + 1 (so worker 0 is always a direct child of the leader — the
//! `dane_round_first` point-to-point path never needs relaying):
//!
//! * children(node k) = { k + 2^j : 2^j > k, k + 2^j <= m }
//! * parent(node k)   = k with its highest set bit cleared
//!
//! which gives the leader ceil(log2(m+1)) direct links and depth
//! O(log m) — the `2·log2(m)` critical path `netmodel::Topology::Tree`
//! models.

use super::netmodel::Topology;
use super::wire::Reply;
use crate::{Error, Result};

/// Which execution strategy a concurrent engine uses for its
/// collectives. Orthogonal to [`crate::config::EngineKind`] (which picks
/// the transport) and mapped onto [`Topology`] for the modeled-seconds
/// accounting via [`ExecTopology::net_topology`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecTopology {
    /// Sequential star: leader-serialized per-worker I/O (baseline).
    StarSeq,
    /// Parallel star: per-connection I/O actors; writes/reads overlap.
    #[default]
    Star,
    /// Binomial-tree relay: workers forward frames to child workers.
    Tree,
}

impl ExecTopology {
    pub fn name(&self) -> &'static str {
        match self {
            ExecTopology::StarSeq => "star-seq",
            ExecTopology::Star => "star",
            ExecTopology::Tree => "tree",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "star-seq" => Ok(ExecTopology::StarSeq),
            "star" => Ok(ExecTopology::Star),
            "tree" => Ok(ExecTopology::Tree),
            other => Err(Error::Config(format!(
                "unknown topology {other:?} (expected \"star\", \"star-seq\" or \"tree\")"
            ))),
        }
    }

    /// Topology named by the environment variable `var` (the figure
    /// benches share `DANE_BENCH_TOPOLOGY`); unset = the default
    /// parallel star, a set but invalid value is an error.
    pub fn from_env(var: &str) -> Result<Self> {
        match std::env::var(var) {
            Ok(v) => Self::from_name(&v),
            Err(std::env::VarError::NotPresent) => Ok(ExecTopology::default()),
            Err(std::env::VarError::NotUnicode(_)) => {
                Err(Error::Config(format!("{var} is not valid UTF-8")))
            }
        }
    }

    /// The network-model topology whose cost matches this execution
    /// strategy. Both star strategies model as [`Topology::Star`]: the
    /// parallel star overlaps the *leader thread's* work, but the
    /// root's single link still serializes the traffic, which is
    /// exactly what the alpha-beta star model charges.
    pub fn net_topology(&self) -> Topology {
        match self {
            ExecTopology::StarSeq | ExecTopology::Star => Topology::Star,
            ExecTopology::Tree => Topology::Tree,
        }
    }

    pub fn is_tree(&self) -> bool {
        matches!(self, ExecTopology::Tree)
    }
}

/// The static shape of the binomial relay tree over `m` workers: who the
/// leader talks to, who relays to whom, and the exact order replies
/// travel upward. Both concurrent engines and the worker serve loop
/// derive their relay behavior from one plan, so the frame-count
/// discipline (every link carries exactly `ranks.len()` replies per
/// round) can never drift between transports.
#[derive(Debug, Clone)]
pub struct TreePlan {
    m: usize,
    /// children[r] = worker r's child ranks, ascending.
    children: Vec<Vec<usize>>,
    /// For each leader-adjacent link: the worker ranks whose replies
    /// travel over it, in up-relay (preorder) order. `root_links[l][0]`
    /// is the root child itself; `root_links[0][0] == 0` always.
    root_links: Vec<Vec<usize>>,
}

impl TreePlan {
    /// Plan the binomial tree for `m >= 1` workers.
    pub fn new(m: usize) -> Self {
        assert!(m >= 1, "tree plan needs >= 1 worker");
        let n = m + 1; // nodes: leader = 0, worker r = node r + 1
        let mut children = vec![Vec::new(); m];
        let mut roots = Vec::new();
        for node in 1..n {
            // children(k) = { k + 2^j : 2^j > k, k + 2^j < n }; node m is
            // the largest, so the loop is bounded.
            let mut p = 1usize;
            while p <= node {
                p <<= 1;
            }
            let rank = node - 1;
            let mut cs = Vec::new();
            while node + p <= m {
                cs.push(node + p - 1); // child node -> child rank
                p <<= 1;
            }
            children[rank] = cs;
            // parent(node) = node with highest bit cleared; direct root
            // children are the powers of two.
            if node.is_power_of_two() {
                roots.push(rank);
            }
        }
        let mut plan = TreePlan { m, children, root_links: Vec::new() };
        plan.root_links = roots
            .into_iter()
            .map(|r| plan.subtree_ranks(r))
            .collect();
        plan
    }

    pub fn m(&self) -> usize {
        self.m
    }

    /// Worker `rank`'s children, ascending.
    pub fn children_of(&self, rank: usize) -> &[usize] {
        &self.children[rank]
    }

    /// The leader-adjacent links: per link, the ranks served through it
    /// in up-relay (preorder) order.
    pub fn root_links(&self) -> &[Vec<usize>] {
        &self.root_links
    }

    /// Whether `rank` is a direct child of the leader.
    pub fn is_root_child(&self, rank: usize) -> bool {
        (rank + 1).is_power_of_two()
    }

    /// Preorder rank list of `rank`'s subtree: the rank itself, then
    /// each child's subtree in child order. This is the exact order a
    /// node sends replies upward, and therefore the order a parent (or
    /// the leader) attributes incoming frames to ranks.
    pub fn subtree_ranks(&self, rank: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.push_subtree(rank, &mut out);
        out
    }

    fn push_subtree(&self, rank: usize, out: &mut Vec<usize>) {
        out.push(rank);
        for &c in &self.children[rank] {
            self.push_subtree(c, out);
        }
    }

    /// Total workers in `rank`'s subtree (itself included).
    pub fn subtree_size(&self, rank: usize) -> usize {
        1 + self
            .children[rank]
            .iter()
            .map(|&c| self.subtree_size(c))
            .sum::<usize>()
    }
}

/// Rank-slotted reply buffer enforcing the fixed-order reduction
/// discipline: replies arrive in whatever order the links deliver them,
/// land in their rank's slot, and the caller folds the slots 0..m in
/// rank order — bit-identical to the serial engine's inline left fold
/// regardless of topology or arrival order.
///
/// Two consumption modes share the slotting and error discipline:
///
/// * **buffered** — [`RankGather::into_result`] /
///   [`RankGather::into_result_masked`] hand back the full rank-ordered
///   reply vector after the gather (per-worker-output collectives:
///   prox, local ERMs);
/// * **incremental** — [`RankGather::drain_fold`] folds reply *i* the
///   moment ranks `0..=i` have all arrived, so the leader's fold work
///   overlaps the remaining network waits. The fold still consumes the
///   slots strictly in rank order (the *prefix* of arrived ranks), so
///   the summation order — and therefore every bit of the result — is
///   identical to the buffered fold. A fold whose round fails midway
///   has touched the accumulator, but the round returns `Err` and every
///   caller discards/refills the accumulator, so no partial fold is
///   ever observed.
///
/// Error discipline matches the engines' historical drain-then-fail
/// contract: every link is drained before anything surfaces, and the
/// error reported is the one belonging to the **lowest rank** (the
/// first the serial engine would have hit). Worker-side
/// [`Reply::Err`] frames are converted to [`Error::Runtime`] here, so
/// both engines name failed workers identically.
pub struct RankGather {
    slots: Vec<Option<Reply>>,
    first_err: Option<(usize, Error)>,
    /// Incremental-fold cursor: every rank below `next` has either been
    /// folded or skipped as quarantined. Stays 0 in buffered mode.
    next: usize,
}

/// Message prefix a relaying node uses when it synthesizes a
/// [`Reply::Err`] for a child whose link died. Both relay
/// implementations (threaded tree workers and the TCP serve loop) emit
/// it, and [`RankGather::put`] keys on it to classify the failure as
/// [`Error::WorkerLost`] — a transport loss observed one hop away, not
/// a deterministic compute error — so supervision can recover from a
/// leaf dying *behind* a live relay.
pub const RELAY_CHILD_LOST: &str = "relay child worker";

impl RankGather {
    pub fn new(m: usize) -> Self {
        RankGather {
            slots: (0..m).map(|_| None).collect(),
            first_err: None,
            next: 0,
        }
    }

    /// Re-arm a pooled gather for a fresh round of `m` ranks. Retains
    /// the slot vector's capacity, so a leader that keeps one
    /// `RankGather` across rounds allocates nothing here in steady
    /// state (`tests/alloc_steady_state.rs`).
    pub fn reset(&mut self, m: usize) {
        self.slots.clear();
        self.slots.resize_with(m, || None);
        self.first_err = None;
        self.next = 0;
    }

    /// Record worker `rank`'s reply (or the transport error that stands
    /// in for it).
    pub fn put(&mut self, rank: usize, reply: Result<Reply>) {
        let err = match reply {
            Ok(Reply::Err(msg)) if msg.starts_with(RELAY_CHILD_LOST) => {
                Error::WorkerLost(format!("worker {rank}: {msg}"))
            }
            Ok(Reply::Err(msg)) => {
                Error::Runtime(format!("worker {rank}: {msg}"))
            }
            Ok(r) => {
                // A rank below the fold cursor already had its reply
                // consumed, so a second arrival is a duplicate even
                // though its slot is empty again.
                if rank >= self.next && self.slots[rank].is_none() {
                    self.slots[rank] = Some(r);
                } else if self.first_err.is_none() {
                    self.first_err = Some((
                        rank,
                        Error::Runtime(format!("worker {rank}: duplicate reply")),
                    ));
                }
                return;
            }
            Err(e) => e,
        };
        match &self.first_err {
            Some((r, _)) if *r <= rank => {}
            _ => self.first_err = Some((rank, err)),
        }
    }

    /// Fold every ready rank-prefix reply: consume slot `next` while
    /// ranks `0..=next` have all arrived (quarantined ranks in `dead`
    /// are expected absentees and are skipped), advancing the cursor.
    /// Call after each [`RankGather::put`] (or batch of puts) to overlap
    /// the leader's fold with outstanding link waits. Once any error is
    /// recorded the fold stops for good — the accumulator is abandoned
    /// and the round surfaces the lowest-rank error from
    /// [`RankGather::finish_fold`].
    pub fn drain_fold(
        &mut self,
        dead: &[bool],
        fold: &mut dyn FnMut(usize, Reply) -> Result<()>,
    ) {
        debug_assert_eq!(dead.len(), self.slots.len(), "dead mask length mismatch");
        while self.first_err.is_none() && self.next < self.slots.len() {
            let rank = self.next;
            if dead.get(rank).copied().unwrap_or(false) {
                if self.slots[rank].is_some() {
                    self.first_err = Some((
                        rank,
                        Error::Runtime(format!(
                            "collective gather: reply from quarantined worker {rank}"
                        )),
                    ));
                    return;
                }
                self.next += 1;
                continue;
            }
            let Some(r) = self.slots[rank].take() else { return };
            if let Err(e) = fold(rank, r) {
                // A fold rejection (wrong reply variant, dimension
                // mismatch) is the same class as a worker-reported bad
                // reply: recorded at this rank. Ranks below it folded
                // clean, so lowest-rank-wins holds by construction.
                self.first_err = Some((rank, e));
                return;
            }
            self.next += 1;
        }
    }

    /// Finish an incremental gather: drain the final prefix, then
    /// surface the lowest-rank error if any reply failed, or a
    /// protocol-violation error if a live rank never replied — the
    /// exact discipline of [`RankGather::into_result_masked`], without
    /// consuming the (pooled) gather. The caller must
    /// [`RankGather::reset`] before the next round either way.
    pub fn finish_fold(
        &mut self,
        dead: &[bool],
        fold: &mut dyn FnMut(usize, Reply) -> Result<()>,
    ) -> Result<()> {
        self.drain_fold(dead, fold);
        if let Some((_, e)) = self.first_err.take() {
            return Err(e);
        }
        if self.next < self.slots.len() {
            return Err(Error::Runtime(format!(
                "collective gather: no reply slotted for worker {}",
                self.next
            )));
        }
        Ok(())
    }

    /// Lowest-rank error recorded so far, if any.
    pub fn failed(&self) -> bool {
        self.first_err.is_some()
    }

    /// Finish the gather: the lowest-rank error if any reply failed,
    /// otherwise every worker's reply in rank order. A silently missing
    /// slot is a protocol violation and fails too — the frame-count
    /// discipline means it can only happen through an engine bug.
    pub fn into_result(self) -> Result<Vec<Reply>> {
        if let Some((_, e)) = self.first_err {
            return Err(e);
        }
        let mut out = Vec::with_capacity(self.slots.len());
        for (rank, s) in self.slots.into_iter().enumerate() {
            match s {
                Some(r) => out.push(r),
                None => {
                    return Err(Error::Runtime(format!(
                        "collective gather: no reply slotted for worker {rank}"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Quorum-degraded finish: ranks flagged in `dead` are *expected* to
    /// be absent and come back as `None`; everything else keeps the
    /// strict [`RankGather::into_result`] discipline (lowest-rank error
    /// wins, a missing reply from a live rank is a protocol violation).
    /// The engines call this only when a `degrade` policy has already
    /// quarantined at least one rank, so the fault-free path is
    /// untouched.
    pub fn into_result_masked(self, dead: &[bool]) -> Result<Vec<Option<Reply>>> {
        if let Some((_, e)) = self.first_err {
            return Err(e);
        }
        assert_eq!(dead.len(), self.slots.len(), "dead mask length mismatch");
        let mut out = Vec::with_capacity(self.slots.len());
        for (rank, s) in self.slots.into_iter().enumerate() {
            match (s, dead[rank]) {
                (Some(r), false) => out.push(Some(r)),
                (None, true) => out.push(None),
                (Some(_), true) => {
                    return Err(Error::Runtime(format!(
                        "collective gather: reply from quarantined worker {rank}"
                    )))
                }
                (None, false) => {
                    return Err(Error::Runtime(format!(
                        "collective gather: no reply slotted for worker {rank}"
                    )))
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_topology_names_roundtrip() {
        for t in [ExecTopology::StarSeq, ExecTopology::Star, ExecTopology::Tree] {
            assert_eq!(ExecTopology::from_name(t.name()).unwrap(), t);
        }
        assert!(ExecTopology::from_name("ring").is_err());
        assert_eq!(ExecTopology::default(), ExecTopology::Star);
        assert_eq!(ExecTopology::Tree.net_topology(), Topology::Tree);
        assert_eq!(ExecTopology::Star.net_topology(), Topology::Star);
        assert_eq!(ExecTopology::StarSeq.net_topology(), Topology::Star);
    }

    #[test]
    fn tree_m4_shape() {
        // nodes 0..=4: children(0)={1,2,4}, children(1)={3} =>
        // root links: workers 0 (with child 2), 1, 3.
        let p = TreePlan::new(4);
        assert_eq!(p.root_links(), &[vec![0, 2], vec![1], vec![3]]);
        assert_eq!(p.children_of(0), &[2]);
        assert_eq!(p.children_of(1), &[] as &[usize]);
        assert_eq!(p.children_of(2), &[] as &[usize]);
        assert_eq!(p.children_of(3), &[] as &[usize]);
        assert!(p.is_root_child(0) && p.is_root_child(1) && p.is_root_child(3));
        assert!(!p.is_root_child(2));
    }

    #[test]
    fn tree_m8_preorder_and_sizes() {
        // nodes 0..=8: root children are nodes {1,2,4,8} = ranks
        // {0,1,3,7}; children(1)={3,5}, children(2)={6}, children(3)={7}
        // at node level => ranks: 0->{2,4}, 1->{5}, 2->{6}.
        let p = TreePlan::new(8);
        assert_eq!(p.children_of(0), &[2, 4]);
        assert_eq!(p.children_of(1), &[5]);
        assert_eq!(p.children_of(2), &[6]);
        assert_eq!(p.children_of(3), &[] as &[usize]);
        assert_eq!(
            p.root_links(),
            &[vec![0, 2, 6, 4], vec![1, 5], vec![3], vec![7]]
        );
        assert_eq!(p.subtree_size(0), 4);
        assert_eq!(p.subtree_size(2), 2);
        assert_eq!(p.subtree_ranks(0), vec![0, 2, 6, 4]);
    }

    #[test]
    fn every_rank_appears_exactly_once_across_root_links() {
        for m in 1..=33 {
            let p = TreePlan::new(m);
            let mut seen = vec![0usize; m];
            for link in p.root_links() {
                assert!(!link.is_empty());
                assert!(p.is_root_child(link[0]));
                for &r in link {
                    seen[r] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "m={m}: {seen:?}");
            // leader degree is logarithmic: ceil(log2(m+1))
            let deg = p.root_links().len();
            assert!(1 << (deg - 1) <= m && (1usize << deg) > m, "m={m} deg={deg}");
            // worker 0 heads the first link — dane_round_first never relays
            assert_eq!(p.root_links()[0][0], 0);
            // parent/child consistency: each child appears once
            let mut child_seen = vec![0usize; m];
            for r in 0..m {
                for &c in p.children_of(r) {
                    assert!(c > r, "child rank must exceed parent rank");
                    child_seen[c] += 1;
                }
            }
            for r in 0..m {
                let expected = usize::from(!p.is_root_child(r));
                assert_eq!(child_seen[r], expected, "m={m} rank={r}");
            }
        }
    }

    #[test]
    fn single_worker_tree_degenerates_to_one_link() {
        let p = TreePlan::new(1);
        assert_eq!(p.root_links(), &[vec![0]]);
        assert_eq!(p.children_of(0), &[] as &[usize]);
    }

    #[test]
    fn rank_gather_orders_and_reports_lowest_rank_error() {
        let mut g = RankGather::new(3);
        g.put(2, Ok(Reply::Scalar(2.0)));
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(1, Ok(Reply::Scalar(1.0)));
        let out = g.into_result().unwrap();
        for (i, r) in out.iter().enumerate() {
            match r {
                Reply::Scalar(x) => assert_eq!(*x, i as f64),
                _ => panic!("wrong variant"),
            }
        }

        let mut g = RankGather::new(3);
        g.put(2, Err(Error::Runtime("late".into())));
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(1, Ok(Reply::Err("boom".into())));
        assert!(g.failed());
        let e = g.into_result().unwrap_err().to_string();
        assert!(e.contains("worker 1") && e.contains("boom"), "{e}");
    }

    #[test]
    fn rank_gather_missing_slot_is_an_error() {
        let mut g = RankGather::new(2);
        g.put(0, Ok(Reply::Scalar(0.0)));
        let e = g.into_result().unwrap_err().to_string();
        assert!(e.contains("no reply slotted for worker 1"), "{e}");
    }

    #[test]
    fn masked_gather_skips_dead_ranks_only() {
        // dead rank 1 absent: fine, comes back as None
        let mut g = RankGather::new(3);
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(2, Ok(Reply::Scalar(2.0)));
        let out = g.into_result_masked(&[false, true, false]).unwrap();
        assert!(out[0].is_some() && out[1].is_none() && out[2].is_some());

        // a live rank missing is still a protocol violation
        let mut g = RankGather::new(3);
        g.put(0, Ok(Reply::Scalar(0.0)));
        let e = g
            .into_result_masked(&[false, true, false])
            .unwrap_err()
            .to_string();
        assert!(e.contains("no reply slotted for worker 2"), "{e}");

        // a reply from a quarantined rank is too
        let mut g = RankGather::new(2);
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(1, Ok(Reply::Scalar(1.0)));
        let e = g
            .into_result_masked(&[false, true])
            .unwrap_err()
            .to_string();
        assert!(e.contains("quarantined worker 1"), "{e}");

        // live-rank errors keep lowest-rank-wins
        let mut g = RankGather::new(3);
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(2, Ok(Reply::Err("boom".into())));
        let e = g
            .into_result_masked(&[false, true, false])
            .unwrap_err()
            .to_string();
        assert!(e.contains("worker 2") && e.contains("boom"), "{e}");
    }

    /// Fold scalars with a weight per rank, recording the fold order —
    /// the incremental-fold tests' stand-in for the engines' axpy fold.
    fn sum_fold(
        acc: &mut f64,
        order: &mut Vec<usize>,
    ) -> impl FnMut(usize, Reply) -> Result<()> + '_ {
        move |rank, r| match r {
            Reply::Scalar(x) => {
                *acc += (rank + 1) as f64 * x;
                order.push(rank);
                Ok(())
            }
            _ => Err(Error::Runtime(format!("worker {rank}: unexpected reply type"))),
        }
    }

    #[test]
    fn incremental_fold_consumes_ready_prefix_in_rank_order() {
        let dead = [false; 4];
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(4);
            // preorder-style arrival (a tree link delivering [0,2,3,1]):
            // rank 0 folds immediately, 2 and 3 buffer until 1 lands.
            g.put(0, Ok(Reply::Scalar(10.0)));
            g.drain_fold(&dead, &mut fold);
            g.put(2, Ok(Reply::Scalar(30.0)));
            g.drain_fold(&dead, &mut fold);
            g.put(3, Ok(Reply::Scalar(40.0)));
            g.drain_fold(&dead, &mut fold);
            g.put(1, Ok(Reply::Scalar(20.0)));
            g.finish_fold(&dead, &mut fold).unwrap();
        }
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert_eq!(acc, 1.0 * 10.0 + 2.0 * 20.0 + 3.0 * 30.0 + 4.0 * 40.0);
    }

    #[test]
    fn incremental_fold_matches_buffered_fold_bitwise() {
        // Same replies, arrival order scrambled differently per mode:
        // the fold order (hence every bit) must not depend on arrival.
        let vals = [0.1, -7.25, 3.5e-3, 1e9, -2.0, 0.625, 55.0];
        let m = vals.len();
        let dead = vec![false; m];
        let buffered = {
            let mut g = RankGather::new(m);
            for r in (0..m).rev() {
                g.put(r, Ok(Reply::Scalar(vals[r])));
            }
            let mut acc = 0.0;
            for (r, rep) in g.into_result().unwrap().into_iter().enumerate() {
                match rep {
                    Reply::Scalar(x) => acc += (r + 1) as f64 * x,
                    _ => panic!("wrong variant"),
                }
            }
            acc
        };
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(m);
            for r in [3, 0, 6, 2, 1, 5, 4] {
                g.put(r, Ok(Reply::Scalar(vals[r])));
                g.drain_fold(&dead, &mut fold);
            }
            g.finish_fold(&dead, &mut fold).unwrap();
        }
        assert_eq!(acc.to_bits(), buffered.to_bits());
        assert_eq!(order, (0..m).collect::<Vec<_>>());
    }

    #[test]
    fn incremental_fold_error_discipline_matches_buffered() {
        // transport error at rank 1: ranks >= 1 never fold, lowest-rank
        // error surfaces from finish_fold
        let dead = [false; 3];
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(3);
            g.put(2, Ok(Reply::Scalar(2.0)));
            g.put(1, Err(Error::Runtime("boom".into())));
            g.put(0, Ok(Reply::Scalar(0.0)));
            let e = g.finish_fold(&dead, &mut fold).unwrap_err().to_string();
            assert!(e.contains("boom"), "{e}");
        }
        assert_eq!(order, vec![0]);

        // a live rank that never replies is a protocol violation
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(3);
            g.put(0, Ok(Reply::Scalar(0.0)));
            g.put(2, Ok(Reply::Scalar(2.0)));
            let e = g.finish_fold(&dead, &mut fold).unwrap_err().to_string();
            assert!(e.contains("no reply slotted for worker 1"), "{e}");
        }

        // a fold rejection (wrong variant) reads like a bad reply
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(2);
            g.put(0, Ok(Reply::Scalar(0.0)));
            g.put(1, Ok(Reply::Vec(vec![1.0])));
            let e = g.finish_fold(&dead[..2], &mut fold).unwrap_err().to_string();
            assert!(e.contains("worker 1") && e.contains("unexpected reply"), "{e}");
        }

        // a second reply for an already-folded rank is a duplicate
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(2);
            g.put(0, Ok(Reply::Scalar(0.0)));
            g.drain_fold(&dead[..2], &mut fold);
            g.put(0, Ok(Reply::Scalar(9.0)));
            g.put(1, Ok(Reply::Scalar(1.0)));
            let e = g.finish_fold(&dead[..2], &mut fold).unwrap_err().to_string();
            assert!(e.contains("duplicate reply"), "{e}");
        }
    }

    #[test]
    fn incremental_fold_skips_quarantined_ranks() {
        let dead = [false, true, false];
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(3);
            g.put(2, Ok(Reply::Scalar(2.0)));
            g.put(0, Ok(Reply::Scalar(0.0)));
            g.finish_fold(&dead, &mut fold).unwrap();
        }
        assert_eq!(order, vec![0, 2]);
        assert_eq!(acc, 3.0 * 2.0);

        // a reply *from* a quarantined rank is still a violation
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            let mut g = RankGather::new(3);
            g.put(0, Ok(Reply::Scalar(0.0)));
            g.put(1, Ok(Reply::Scalar(1.0)));
            g.put(2, Ok(Reply::Scalar(2.0)));
            let e = g.finish_fold(&dead, &mut fold).unwrap_err().to_string();
            assert!(e.contains("quarantined worker 1"), "{e}");
        }
    }

    #[test]
    fn reset_rearms_a_pooled_gather_without_reallocating() {
        let dead = [false; 2];
        let mut g = RankGather::new(2);
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            g.put(1, Err(Error::Runtime("boom".into())));
            g.put(0, Ok(Reply::Scalar(0.5)));
            assert!(g.finish_fold(&dead, &mut fold).is_err());
        }
        // after an error the pooled gather re-arms clean
        g.reset(2);
        let mut acc = 0.0;
        let mut order = Vec::new();
        {
            let mut fold = sum_fold(&mut acc, &mut order);
            g.put(0, Ok(Reply::Scalar(1.0)));
            g.put(1, Ok(Reply::Scalar(2.0)));
            g.finish_fold(&dead, &mut fold).unwrap();
        }
        assert_eq!(acc, 1.0 + 2.0 * 2.0);
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn relayed_child_death_classifies_as_worker_lost() {
        let mut g = RankGather::new(2);
        g.put(0, Ok(Reply::Scalar(0.0)));
        g.put(
            1,
            Ok(Reply::Err(format!("{RELAY_CHILD_LOST} 1 died mid-round"))),
        );
        match g.into_result().unwrap_err() {
            Error::WorkerLost(msg) => {
                assert!(msg.contains("worker 1"), "{msg}")
            }
            other => panic!("expected WorkerLost, got {other}"),
        }

        // an ordinary worker-computed error stays Runtime
        let mut g = RankGather::new(1);
        g.put(0, Ok(Reply::Err("singular system".into())));
        assert!(matches!(
            g.into_result().unwrap_err(),
            Error::Runtime(_)
        ));
    }
}
