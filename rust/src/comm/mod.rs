//! Simulated collective communication layer.
//!
//! Communication rounds are the paper's evaluation currency: DANE costs
//! exactly two distributed averages per iteration, GD one, OSA one total.
//! This module provides the averaging primitives, *counts* every byte and
//! round (so benches can report them), and attaches an alpha-beta network
//! cost model with star / ring / tree topologies to turn counts into
//! modeled wallclock — the quantity a real deployment would observe.

pub mod collective;
pub mod netmodel;
pub mod roundchan;

pub use collective::{Collective, CommStats};
pub use netmodel::{NetModel, Topology};
pub use roundchan::{round_channel, RoundReceiver, RoundSender};
