//! Simulated collective communication layer.
//!
//! Communication rounds are the paper's evaluation currency: DANE costs
//! exactly two distributed averages per iteration, GD one, OSA one total.
//! This module provides the averaging primitives, *counts* every byte and
//! round (so benches can report them), and attaches an alpha-beta network
//! cost model with star / ring / tree topologies to turn counts into
//! modeled wallclock — the quantity a real deployment would observe.
//!
//! [`wire`] is the protocol made explicit: typed `Command`/`Reply`
//! messages plus a binary codec, shared by the in-memory engines and the
//! TCP process cluster. Alongside the *modeled* figures, `CommStats`
//! carries `wire_bytes` — bytes actually moved over a socket (zero on
//! in-memory engines).
//!
//! [`topology`] makes the modeled topologies *executable*: the
//! concurrent engines select sequential-star, parallel-star or
//! binomial-tree-relay collective execution through
//! [`topology::ExecTopology`], with the tree shape and the fixed-order
//! reduction discipline (`topology::{TreePlan, RankGather}`) shared by
//! both transports so traces stay bit-identical across the whole
//! engine × topology matrix.

//!
//! [`compress`] shrinks the O(d) round payloads themselves: three codecs
//! (f32 downcast, deterministic top-k, seeded stochastic quantization)
//! plus error-feedback accumulators, carried by the
//! `Command::CompressedVec` / `Reply::CompressedVec` frame variants so
//! both concurrent engines and every topology move fewer real bytes while
//! converging to the same quality.

pub mod collective;
pub mod compress;
pub mod netmodel;
pub mod roundchan;
pub mod topology;
pub mod wire;

pub use collective::{Collective, CommStats};
pub use netmodel::{NetModel, Topology};
pub use roundchan::{round_channel, RoundReceiver, RoundSender};
pub use topology::{ExecTopology, RankGather, TreePlan};
