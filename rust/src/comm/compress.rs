//! Wire-level payload compression with error feedback.
//!
//! Three codecs shrink the O(d) round vectors that dominate DANE/GD/AGD
//! traffic:
//!
//! - `F32` — per-element downcast to `f32` (2x, deterministic, lossy in the
//!   low mantissa bits only).
//! - `TopK { k }` — keep the `k` entries of largest magnitude; ties break
//!   toward the lower index so the selected support is identical on every
//!   platform. Indices travel sorted ascending.
//! - `Quant { bits }` — QSGD-style stochastic quantization against the
//!   vector's L-inf norm: each entry becomes a sign bit plus a `bits`-bit
//!   level, rounded stochastically from a seeded [`Rng64`] stream so both
//!   engines produce byte-identical payloads.
//!
//! Lossy codecs alone stall convergence; pairing them with error-feedback
//! accumulators (Islamov–Qian–Richtarik 2021) restores it. Each direction
//! of each compressed stream keeps a residual `e`: we transmit
//! `c = C(x + e)` and update `e <- (x + e) - D(c)`, so quantization error
//! is re-injected on later rounds instead of being lost.
//!
//! The leader holds one [`LeaderCompressor`] per cluster (streams for the
//! broadcast iterate and gradient); each worker holds a
//! [`WorkerCompressor`] (streams for its gradient and solve replies).
//! Worker quantization seeds are derived from the per-round seed carried in
//! the command spec mixed with the worker rank, so replies are reproducible
//! without any shared state.
//!
//! Everything here is on the coordinator/worker hot path and must never
//! panic on any input (dane-lint panic-freedom applies to this module).

use crate::util::rng::Rng64;

/// Stream identifiers folded into quantization seeds so the five
/// compressed directions draw from disjoint random streams.
const STREAM_GRAD_W: u64 = 1;
const STREAM_SOLVE_WPREV: u64 = 2;
const STREAM_SOLVE_G: u64 = 3;
const STREAM_GRAD_REPLY: u64 = 4;
const STREAM_SOLVE_REPLY: u64 = 5;

/// Frame overhead shared by every wire frame: 4-byte length prefix,
/// 1 version byte, 1 tag byte. Mirrors the layout in `comm::wire`.
const FRAME_OVERHEAD: u64 = 6;

/// splitmix64 finalizer; good avalanche for cheap seed derivation.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seed for a leader-side stream at a given round.
fn stream_seed(base: u64, stream: u64, round: u64) -> u64 {
    let s = stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let r = round.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    mix(base ^ s ^ r)
}

/// Seed a worker derives for its reply from the spec seed and its rank.
pub fn reply_seed_for_rank(reply_seed: u64, rank: u64) -> u64 {
    mix(reply_seed ^ rank.wrapping_mul(0xD6E8_FEB8_6659_FD93))
}

/// Which codec to apply to round payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Codec {
    /// Downcast every element to `f32`.
    F32,
    /// Keep the `k` largest-magnitude entries (lower index wins ties).
    TopK { k: usize },
    /// Seeded stochastic quantization with `bits` level bits per element
    /// (plus one sign bit). `bits` must be in `1..=8`.
    Quant { bits: u8 },
}

impl Codec {
    /// Short human-readable name for logs and bench output.
    pub fn name(&self) -> &'static str {
        match self {
            Codec::F32 => "f32",
            Codec::TopK { .. } => "topk",
            Codec::Quant { .. } => "quant",
        }
    }
}

/// Which round operation a compressed command stands in for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressedOp {
    /// `Command::GradLoss` — one broadcast vector (the iterate), reply is a
    /// gradient plus local loss.
    GradLoss,
    /// `Command::DaneSolve` — two broadcast vectors (`w_prev`, `g`) plus
    /// `eta`/`mu`, reply is the local minimizer.
    DaneSolve,
}

impl CompressedOp {
    /// Number of broadcast vectors this operation carries.
    pub fn nvecs(&self) -> usize {
        match self {
            CompressedOp::GradLoss => 1,
            CompressedOp::DaneSolve => 2,
        }
    }
}

/// How the worker must compress its reply: codec, whether to run its
/// error-feedback accumulator, and the round's base quantization seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReplySpec {
    pub codec: Codec,
    pub error_feedback: bool,
    pub seed: u64,
}

/// A compressed vector as it travels on the wire.
#[derive(Debug, Clone, PartialEq)]
pub enum CodedVec {
    F32 { data: Vec<f32> },
    TopK { dim: usize, idx: Vec<u32>, val: Vec<f64> },
    Quant { dim: usize, norm: f64, bits: u8, packed: Vec<u8> },
}

/// Payload of `Command::CompressedVec`: a round command whose vectors are
/// codec-encoded, plus the spec the worker must use for its reply.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedCmd {
    pub op: CompressedOp,
    pub eta: f64,
    pub mu: f64,
    pub spec: ReplySpec,
    pub vecs: Vec<CodedVec>,
}

/// Payload of `Reply::CompressedVec`: a codec-encoded result vector plus
/// the scalar local loss when the operation produces one.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressedReply {
    pub loss: Option<f64>,
    pub vec: CodedVec,
}

impl CodedVec {
    /// Logical (decompressed) dimension.
    pub fn dim(&self) -> usize {
        match self {
            CodedVec::F32 { data } => data.len(),
            CodedVec::TopK { dim, .. } => *dim,
            CodedVec::Quant { dim, .. } => *dim,
        }
    }

    /// Exact number of bytes this vector occupies inside a frame body
    /// (codec byte included). Must agree with the `comm::wire` encoding;
    /// pinned by a test there.
    pub fn wire_len(&self) -> u64 {
        match self {
            CodedVec::F32 { data } => 1 + 8 + 4 * data.len() as u64,
            CodedVec::TopK { idx, .. } => 1 + 8 + 8 + 12 * idx.len() as u64,
            CodedVec::Quant { packed, .. } => 1 + 8 + 8 + 1 + packed.len() as u64,
        }
    }

    /// Compress `x` with `codec`. `rng` is consumed only by `Quant`
    /// (exactly one draw per element, so the stream stays aligned).
    pub fn encode(codec: Codec, x: &[f64], rng: &mut Rng64) -> CodedVec {
        match codec {
            Codec::F32 => CodedVec::F32 { data: x.iter().map(|&v| v as f32).collect() },
            Codec::TopK { k } => encode_topk(x, k),
            Codec::Quant { bits } => encode_quant(x, bits.clamp(1, 8), rng),
        }
    }

    /// Reconstruct into `out`, resizing it to `self.dim()`. Infallible:
    /// callers validate `dim()` against the expected dimension first.
    pub fn decode_into(&self, out: &mut Vec<f64>) {
        out.clear();
        match self {
            CodedVec::F32 { data } => out.extend(data.iter().map(|&v| v as f64)),
            CodedVec::TopK { dim, idx, val } => {
                out.resize(*dim, 0.0);
                for (&i, &v) in idx.iter().zip(val.iter()) {
                    if let Some(slot) = out.get_mut(i as usize) {
                        *slot = v;
                    }
                }
            }
            CodedVec::Quant { dim, norm, bits, packed } => {
                let bits = (*bits).clamp(1, 8);
                let scale = ((1u32 << bits) - 1) as f64;
                let mut r = BitReader { bytes: packed, pos: 0, acc: 0, nbits: 0 };
                out.reserve(*dim);
                for _ in 0..*dim {
                    let sign = r.take(1) == 1;
                    let level = r.take(u32::from(bits)) as f64;
                    let mut v = norm * level / scale;
                    if sign {
                        v = -v;
                    }
                    out.push(v);
                }
            }
        }
    }
}

/// Deterministic top-k selection: largest magnitude wins; `total_cmp`
/// keeps the comparator a strict total order (so NaN inputs still select
/// deterministically), and equal magnitudes break toward the lower index.
fn encode_topk(x: &[f64], k: usize) -> CodedVec {
    let d = x.len();
    let k = k.min(d);
    let mut order: Vec<u32> = (0..d as u32).collect();
    let by_mag = |&a: &u32, &b: &u32| {
        x[b as usize]
            .abs()
            .total_cmp(&x[a as usize].abs())
            .then_with(|| a.cmp(&b))
    };
    if k > 0 && k < d {
        order.select_nth_unstable_by(k - 1, by_mag);
    }
    let mut idx: Vec<u32> = order.into_iter().take(k).collect();
    idx.sort_unstable();
    let val: Vec<f64> = idx.iter().map(|&i| x[i as usize]).collect();
    CodedVec::TopK { dim: d, idx, val }
}

/// Stochastic quantization against the L-inf norm: one sign bit plus a
/// `bits`-bit level per element. Exactly one rng draw per element.
fn encode_quant(x: &[f64], bits: u8, rng: &mut Rng64) -> CodedVec {
    let d = x.len();
    let levels = (1u32 << bits) - 1;
    let scale = levels as f64;
    let norm = x.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
    let mut w = BitWriter::new();
    for &v in x {
        let sign = v.is_sign_negative();
        let level = if norm > 0.0 && v.abs().is_finite() {
            let r = (v.abs() / norm) * scale;
            let lo = r.floor();
            let p = r - lo;
            let up = if rng.f64() < p { 1 } else { 0 };
            // Casting a non-finite or huge `lo` saturates, never panics.
            (lo as u32).saturating_add(up).min(levels)
        } else {
            // Zero vector, or a non-finite element against a non-finite
            // norm: emit level 0 but keep the rng stream aligned.
            let _ = rng.f64();
            0
        };
        w.push(u32::from(sign), 1);
        w.push(level, u32::from(bits));
    }
    CodedVec::Quant { dim: d, norm, bits, packed: w.finish() }
}

/// Number of packed bytes a `Quant` payload of `dim` elements at `bits`
/// level bits occupies. Computed in u128 so hostile dims cannot overflow.
pub fn quant_packed_len(dim: u64, bits: u8) -> u128 {
    (u128::from(dim) * (u128::from(bits) + 1)).div_ceil(8)
}

/// LSB-first bit packer for quantized payloads.
struct BitWriter {
    bytes: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), acc: 0, nbits: 0 }
    }

    fn push(&mut self, value: u32, width: u32) {
        let mask = if width >= 32 { u32::MAX } else { (1u32 << width) - 1 };
        self.acc |= u64::from(value & mask) << self.nbits;
        self.nbits += width;
        while self.nbits >= 8 {
            self.bytes.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.bytes.push((self.acc & 0xff) as u8);
        }
        self.bytes
    }
}

/// LSB-first bit reader; reads past the end yield zeros (callers validate
/// the packed length on the wire, this just guarantees no panic).
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl BitReader<'_> {
    fn take(&mut self, width: u32) -> u32 {
        while self.nbits < width {
            let b = self.bytes.get(self.pos).copied().unwrap_or(0);
            self.pos += 1;
            self.acc |= u64::from(b) << self.nbits;
            self.nbits += 8;
        }
        let mask = if width >= 32 { u32::MAX } else { (1u32 << width) - 1 };
        let v = (self.acc as u32) & mask;
        self.acc >>= width;
        self.nbits -= width;
        v
    }
}

/// One direction of an error-feedback accumulator: residual plus scratch
/// buffers so steady-state rounds do not allocate.
#[derive(Debug, Default)]
struct Stream {
    residual: Vec<f64>,
    shifted: Vec<f64>,
    decoded: Vec<f64>,
}

impl Stream {
    /// Compress `x`; when `ef` is set, compress `x + residual` and fold
    /// the reconstruction error back into the residual.
    fn encode(&mut self, codec: Codec, ef: bool, x: &[f64], rng: &mut Rng64) -> CodedVec {
        if !ef {
            return CodedVec::encode(codec, x, rng);
        }
        if self.residual.len() != x.len() {
            self.residual.clear();
            self.residual.resize(x.len(), 0.0);
        }
        self.shifted.clear();
        self.shifted
            .extend(x.iter().zip(self.residual.iter()).map(|(&a, &b)| a + b));
        let coded = CodedVec::encode(codec, &self.shifted, rng);
        coded.decode_into(&mut self.decoded);
        for ((e, &t), &dec) in self
            .residual
            .iter_mut()
            .zip(self.shifted.iter())
            .zip(self.decoded.iter())
        {
            *e = t - dec;
        }
        coded
    }
}

/// Leader-side compressor: owns the broadcast-direction error-feedback
/// streams and the per-round seed schedule. One per cluster.
#[derive(Debug)]
pub struct LeaderCompressor {
    codec: Codec,
    error_feedback: bool,
    seed: u64,
    round: u64,
    grad_w: Stream,
    solve_wprev: Stream,
    solve_g: Stream,
}

impl LeaderCompressor {
    pub fn new(codec: Codec, error_feedback: bool, seed: u64) -> Self {
        LeaderCompressor {
            codec,
            error_feedback,
            seed,
            round: 0,
            grad_w: Stream::default(),
            solve_wprev: Stream::default(),
            solve_g: Stream::default(),
        }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    fn reply_spec(&self, stream: u64) -> ReplySpec {
        ReplySpec {
            codec: self.codec,
            error_feedback: self.error_feedback,
            seed: stream_seed(self.seed, stream, self.round),
        }
    }

    /// Build the compressed equivalent of `Command::GradLoss { w }`.
    /// Advances the round counter (both engines call this once per round,
    /// in the same order, so their rng schedules agree).
    pub fn grad_cmd(&mut self, w: &[f64]) -> CompressedCmd {
        self.round += 1;
        let spec = self.reply_spec(STREAM_GRAD_REPLY);
        let mut rng = Rng64::seed_from_u64(stream_seed(self.seed, STREAM_GRAD_W, self.round));
        let coded = self
            .grad_w
            .encode(self.codec, self.error_feedback, w, &mut rng);
        CompressedCmd {
            op: CompressedOp::GradLoss,
            eta: 0.0,
            mu: 0.0,
            spec,
            vecs: vec![coded],
        }
    }

    /// Build the compressed equivalent of `Command::DaneSolve`.
    pub fn solve_cmd(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64) -> CompressedCmd {
        self.round += 1;
        let spec = self.reply_spec(STREAM_SOLVE_REPLY);
        let mut rng_w =
            Rng64::seed_from_u64(stream_seed(self.seed, STREAM_SOLVE_WPREV, self.round));
        let coded_w = self
            .solve_wprev
            .encode(self.codec, self.error_feedback, w_prev, &mut rng_w);
        let mut rng_g = Rng64::seed_from_u64(stream_seed(self.seed, STREAM_SOLVE_G, self.round));
        let coded_g = self
            .solve_g
            .encode(self.codec, self.error_feedback, g, &mut rng_g);
        CompressedCmd {
            op: CompressedOp::DaneSolve,
            eta,
            mu,
            spec,
            vecs: vec![coded_w, coded_g],
        }
    }
}

/// Worker-side compressor: reply-direction error-feedback streams plus
/// decode/compute scratch, kept on the `Worker` so steady-state rounds do
/// not allocate.
#[derive(Debug, Default)]
pub struct WorkerCompressor {
    grad: Stream,
    solve: Stream,
    /// Scratch for the decoded broadcast iterate.
    pub w_buf: Vec<f64>,
    /// Scratch for the decoded broadcast gradient (DaneSolve only).
    pub g_buf: Vec<f64>,
    /// Scratch for the computed result before reply compression.
    pub out: Vec<f64>,
}

impl WorkerCompressor {
    /// Compress a reply vector per the command's spec. `rank` decorrelates
    /// the quantization streams across workers.
    pub fn encode_reply(
        &mut self,
        op: CompressedOp,
        spec: &ReplySpec,
        rank: u64,
        x: &[f64],
    ) -> CodedVec {
        let mut rng = Rng64::seed_from_u64(reply_seed_for_rank(spec.seed, rank));
        let stream = match op {
            CompressedOp::GradLoss => &mut self.grad,
            CompressedOp::DaneSolve => &mut self.solve,
        };
        stream.encode(spec.codec, spec.error_feedback, x, &mut rng)
    }
}

impl CompressedReply {
    /// Exact encoded frame length (length prefix through last payload
    /// byte) of this reply on the wire. Pinned against the real encoder by
    /// a test in `comm::wire`.
    pub fn frame_len(&self) -> u64 {
        let loss_len = if self.loss.is_some() { 8 } else { 0 };
        FRAME_OVERHEAD + 1 + loss_len + self.vec.wire_len()
    }
}

/// Frame length of the uncompressed command `op` would otherwise ship
/// (`GradLoss` encodes one vector; `DaneSolve` two vectors plus
/// `eta`/`mu`). Used for the `payload_bytes_raw` accounting column.
pub fn raw_cmd_frame_len(op: CompressedOp, d: usize) -> u64 {
    let vec_len = 8 + 8 * d as u64;
    match op {
        CompressedOp::GradLoss => FRAME_OVERHEAD + vec_len,
        CompressedOp::DaneSolve => FRAME_OVERHEAD + 2 * vec_len + 16,
    }
}

/// Frame length of the uncompressed reply to `op` (`GradLoss` answers
/// with `Reply::VecScalar`; `DaneSolve` with `Reply::Vec`).
pub fn raw_reply_frame_len(op: CompressedOp, d: usize) -> u64 {
    let vec_len = 8 + 8 * d as u64;
    match op {
        CompressedOp::GradLoss => FRAME_OVERHEAD + vec_len + 8,
        CompressedOp::DaneSolve => FRAME_OVERHEAD + vec_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng64 {
        Rng64::seed_from_u64(0xD1CE)
    }

    fn decode(c: &CodedVec) -> Vec<f64> {
        let mut out = Vec::new();
        c.decode_into(&mut out);
        out
    }

    #[test]
    fn f32_roundtrip_preserves_f32_representable_values() {
        let x = vec![1.5, -2.25, 0.0, -0.0, 3.0e7];
        let c = CodedVec::encode(Codec::F32, &x, &mut rng());
        let y = decode(&c);
        for (a, b) in x.iter().zip(y.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn f32_passes_nonfinite_bit_patterns_through() {
        let x = vec![f64::NAN, f64::INFINITY, f64::NEG_INFINITY];
        let y = decode(&CodedVec::encode(Codec::F32, &x, &mut rng()));
        assert!(y[0].is_nan());
        assert_eq!(y[1], f64::INFINITY);
        assert_eq!(y[2], f64::NEG_INFINITY);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let x = vec![0.1, -5.0, 0.0, 3.0, -0.2];
        let c = CodedVec::encode(Codec::TopK { k: 2 }, &x, &mut rng());
        match &c {
            CodedVec::TopK { dim, idx, val } => {
                assert_eq!(*dim, 5);
                assert_eq!(idx, &[1, 3]);
                assert_eq!(val, &[-5.0, 3.0]);
            }
            other => panic!("wrong codec: {other:?}"),
        }
        assert_eq!(decode(&c), vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_tie_breaks_toward_lower_index() {
        let x = vec![2.0, -2.0, 2.0, -2.0];
        let c = CodedVec::encode(Codec::TopK { k: 2 }, &x, &mut rng());
        match c {
            CodedVec::TopK { idx, .. } => assert_eq!(idx, vec![0, 1]),
            other => panic!("wrong codec: {other:?}"),
        }
    }

    #[test]
    fn topk_k_larger_than_dim_is_lossless() {
        let x = vec![1.0, -2.0, 3.0];
        let c = CodedVec::encode(Codec::TopK { k: 10 }, &x, &mut rng());
        assert_eq!(decode(&c), x);
    }

    #[test]
    fn topk_empty_and_k_zero() {
        let empty = CodedVec::encode(Codec::TopK { k: 3 }, &[], &mut rng());
        assert_eq!(decode(&empty), Vec::<f64>::new());
        let x = vec![1.0, 2.0];
        let none = CodedVec::encode(Codec::TopK { k: 0 }, &x, &mut rng());
        assert_eq!(decode(&none), vec![0.0, 0.0]);
    }

    #[test]
    fn quant_roundtrip_bounded_error_and_determinism() {
        let x: Vec<f64> = (0..97).map(|i| ((i * 37) % 19) as f64 - 9.0).collect();
        for bits in [1u8, 2, 4, 8] {
            let c1 = CodedVec::encode(Codec::Quant { bits }, &x, &mut Rng64::seed_from_u64(7));
            let c2 = CodedVec::encode(Codec::Quant { bits }, &x, &mut Rng64::seed_from_u64(7));
            assert_eq!(c1, c2, "same seed must give identical payloads");
            let y = decode(&c1);
            let norm = 9.0;
            let step = norm / ((1u32 << bits) - 1) as f64;
            for (a, b) in x.iter().zip(y.iter()) {
                assert!((a - b).abs() <= step + 1e-12, "bits={bits} a={a} b={b}");
                if *b != 0.0 {
                    assert_eq!(a.is_sign_negative(), b.is_sign_negative());
                }
            }
        }
    }

    #[test]
    fn quant_zero_vector_and_empty() {
        let c = CodedVec::encode(Codec::Quant { bits: 4 }, &[0.0, 0.0, 0.0], &mut rng());
        assert_eq!(decode(&c), vec![0.0, 0.0, 0.0]);
        let c = CodedVec::encode(Codec::Quant { bits: 4 }, &[], &mut rng());
        assert_eq!(decode(&c), Vec::<f64>::new());
        match c {
            CodedVec::Quant { packed, .. } => assert!(packed.is_empty()),
            other => panic!("wrong codec: {other:?}"),
        }
    }

    #[test]
    fn quant_nonfinite_inputs_do_not_panic() {
        let x = vec![f64::NAN, f64::INFINITY, -1.0, f64::NEG_INFINITY];
        let c = CodedVec::encode(Codec::Quant { bits: 3 }, &x, &mut rng());
        // The resulting norm is non-finite, so the payload would be rejected
        // at the wire boundary — what matters here is that encode/decode of
        // pathological inputs never panics and the dimension survives.
        assert_eq!(decode(&c).len(), 4);
    }

    #[test]
    fn quant_packed_len_matches_encoder() {
        for (d, bits) in [(0usize, 1u8), (1, 1), (7, 3), (8, 8), (97, 5)] {
            let x = vec![1.0; d];
            let c = CodedVec::encode(Codec::Quant { bits }, &x, &mut rng());
            match c {
                CodedVec::Quant { packed, .. } => {
                    assert_eq!(packed.len() as u128, quant_packed_len(d as u64, bits));
                }
                other => panic!("wrong codec: {other:?}"),
            }
        }
    }

    #[test]
    fn error_feedback_residual_recovers_topk_loss() {
        // With EF, the sum of transmitted estimates over many rounds tracks
        // the sum of inputs: feed the same x repeatedly and check that the
        // averaged reconstruction approaches x even though each round ships
        // only 1 of 8 coordinates.
        let x = vec![4.0, -3.0, 2.0, -1.5, 1.0, -0.5, 0.25, -0.125];
        let codec = Codec::TopK { k: 1 };
        let mut stream = Stream::default();
        let mut sum = vec![0.0; x.len()];
        let rounds = 400;
        for r in 0..rounds {
            let mut rng = Rng64::seed_from_u64(r);
            let c = stream.encode(codec, true, &x, &mut rng);
            let mut dec = Vec::new();
            c.decode_into(&mut dec);
            for (s, d) in sum.iter_mut().zip(dec.iter()) {
                *s += d;
            }
        }
        for (a, s) in x.iter().zip(sum.iter()) {
            let avg = s / rounds as f64;
            assert!((a - avg).abs() < 0.15, "a={a} avg={avg}");
        }
    }

    #[test]
    fn error_feedback_resets_on_dim_change() {
        let mut stream = Stream::default();
        let mut rng = rng();
        let _ = stream.encode(Codec::TopK { k: 1 }, &[1.0, 2.0, 3.0], &mut rng);
        assert_eq!(stream.residual.len(), 3);
        let _ = stream.encode(Codec::TopK { k: 1 }, &[1.0, 2.0], &mut rng);
        assert_eq!(stream.residual.len(), 2);
    }

    #[test]
    fn leader_compressor_round_schedule_is_deterministic() {
        let w = vec![0.5, -1.5, 2.5, -3.5];
        let g = vec![1.0, 0.0, -1.0, 2.0];
        let mut a = LeaderCompressor::new(Codec::Quant { bits: 4 }, true, 99);
        let mut b = LeaderCompressor::new(Codec::Quant { bits: 4 }, true, 99);
        for _ in 0..3 {
            assert_eq!(a.grad_cmd(&w), b.grad_cmd(&w));
            assert_eq!(a.solve_cmd(&w, &g, 1.0, 0.1), b.solve_cmd(&w, &g, 1.0, 0.1));
        }
        // Different base seed diverges for stochastic codecs.
        let mut c = LeaderCompressor::new(Codec::Quant { bits: 4 }, true, 100);
        assert_ne!(a.grad_cmd(&w), c.grad_cmd(&w));
    }

    #[test]
    fn worker_compressor_ranks_decorrelate() {
        let spec = ReplySpec { codec: Codec::Quant { bits: 2 }, error_feedback: false, seed: 7 };
        let x: Vec<f64> = (0..64).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut w0 = WorkerCompressor::default();
        let mut w1 = WorkerCompressor::default();
        let c0 = w0.encode_reply(CompressedOp::GradLoss, &spec, 0, &x);
        let c1 = w1.encode_reply(CompressedOp::GradLoss, &spec, 1, &x);
        assert_ne!(c0, c1, "distinct ranks must draw distinct streams");
        let mut w0b = WorkerCompressor::default();
        assert_eq!(c0, w0b.encode_reply(CompressedOp::GradLoss, &spec, 0, &x));
    }

    #[test]
    fn raw_frame_len_formulas() {
        assert_eq!(raw_cmd_frame_len(CompressedOp::GradLoss, 4), 6 + 8 + 32);
        assert_eq!(raw_cmd_frame_len(CompressedOp::DaneSolve, 4), 6 + 2 * 40 + 16);
        assert_eq!(raw_reply_frame_len(CompressedOp::GradLoss, 4), 6 + 40 + 8);
        assert_eq!(raw_reply_frame_len(CompressedOp::DaneSolve, 4), 6 + 8 + 32);
    }

    #[test]
    fn wire_len_matches_struct_contents() {
        let f = CodedVec::F32 { data: vec![1.0, 2.0, 3.0] };
        assert_eq!(f.wire_len(), 1 + 8 + 12);
        let t = CodedVec::TopK { dim: 10, idx: vec![1, 2], val: vec![5.0, -5.0] };
        assert_eq!(t.wire_len(), 1 + 16 + 24);
        let q = CodedVec::Quant { dim: 8, norm: 1.0, bits: 3, packed: vec![0; 4] };
        assert_eq!(q.wire_len(), 1 + 8 + 8 + 1 + 4);
    }
}
