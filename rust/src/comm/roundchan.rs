//! Allocation-free single-slot rendezvous channel.
//!
//! The leader/worker round protocol in
//! [`crate::coordinator::threaded::ThreadedCluster`] is strictly
//! lockstep: one command down, one reply up, per worker, per round. A
//! general mpsc queue pays for that generality with heap-allocated queue
//! nodes on every send — which would be the only allocation left in a
//! steady-state DANE round. This channel replaces the queue with a
//! single `Option<T>` slot guarded by a `Mutex` + `Condvar` (futex-backed
//! on Linux): `send` moves the value into the slot, `recv` moves it out,
//! and neither touches the heap after construction. The zero-allocation
//! contract is pinned by the counting-allocator test
//! `rust/tests/alloc_steady_state.rs`.
//!
//! Disconnect semantics mirror `std::sync::mpsc`: dropping the receiver
//! makes `send` fail, dropping the sender makes `recv` fail once the
//! slot is drained — so a panicking worker thread (unwinding drops its
//! endpoints) surfaces as an `Err` on the leader, never a deadlock.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

struct Slot<T> {
    value: Option<T>,
    tx_alive: bool,
    rx_alive: bool,
}

struct Shared<T> {
    slot: Mutex<Slot<T>>,
    cv: Condvar,
}

/// Sending half; dropping it disconnects the channel.
pub struct RoundSender<T>(Arc<Shared<T>>);

/// Receiving half; dropping it disconnects the channel.
pub struct RoundReceiver<T>(Arc<Shared<T>>);

/// Error returned by [`RoundSender::send`] when the receiver is gone;
/// carries the unsent value back, like `std::sync::mpsc::SendError`.
#[derive(Debug)]
pub struct SendError<T>(pub T);

/// Error returned by [`RoundReceiver::recv`] when the sender is gone and
/// the slot is empty.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvError;

/// Error returned by [`RoundReceiver::recv_timeout`]: either the peer is
/// gone ([`RecvTimeoutError::Disconnected`], same as [`RecvError`]) or it
/// is *wedged* — alive but silent past the deadline. Mirrors
/// `std::sync::mpsc::RecvTimeoutError`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

/// Create a connected single-slot channel pair.
pub fn round_channel<T>() -> (RoundSender<T>, RoundReceiver<T>) {
    let shared = Arc::new(Shared {
        slot: Mutex::new(Slot { value: None, tx_alive: true, rx_alive: true }),
        cv: Condvar::new(),
    });
    (RoundSender(shared.clone()), RoundReceiver(shared))
}

impl<T> RoundSender<T> {
    /// Move `v` into the slot, blocking while the previous value is
    /// still unclaimed. Fails (returning `v`) if the receiver is gone.
    pub fn send(&self, v: T) -> std::result::Result<(), SendError<T>> {
        let mut slot = lock(&self.0.slot);
        loop {
            if !slot.rx_alive {
                return Err(SendError(v));
            }
            if slot.value.is_none() {
                slot.value = Some(v);
                self.0.cv.notify_all();
                return Ok(());
            }
            slot = wait(&self.0.cv, slot);
        }
    }
}

impl<T> RoundReceiver<T> {
    /// Take the slot value, blocking until one arrives. Fails once the
    /// sender is gone and the slot is drained.
    pub fn recv(&self) -> std::result::Result<T, RecvError> {
        let mut slot = lock(&self.0.slot);
        loop {
            if let Some(v) = slot.value.take() {
                self.0.cv.notify_all();
                return Ok(v);
            }
            if !slot.tx_alive {
                return Err(RecvError);
            }
            slot = wait(&self.0.cv, slot);
        }
    }

    /// Like [`RoundReceiver::recv`], but gives up after `timeout` — the
    /// hang-safety primitive: a worker that is wedged (not just dead)
    /// surfaces as `Err(Timeout)` on the leader instead of a deadlock.
    /// Allocation-free like `recv`, so the steady-state protocol can use
    /// it unconditionally.
    pub fn recv_timeout(
        &self,
        timeout: Duration,
    ) -> std::result::Result<T, RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut slot = lock(&self.0.slot);
        loop {
            if let Some(v) = slot.value.take() {
                self.0.cv.notify_all();
                return Ok(v);
            }
            if !slot.tx_alive {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            slot = wait_timeout(&self.0.cv, slot, deadline - now);
        }
    }
}

impl<T> Drop for RoundSender<T> {
    fn drop(&mut self) {
        lock(&self.0.slot).tx_alive = false;
        self.0.cv.notify_all();
    }
}

impl<T> Drop for RoundReceiver<T> {
    fn drop(&mut self) {
        lock(&self.0.slot).rx_alive = false;
        self.0.cv.notify_all();
    }
}

/// Lock, shrugging off poisoning: the slot holds plain moved data, so a
/// panicked peer cannot leave it logically inconsistent.
fn lock<'a, T>(m: &'a Mutex<Slot<T>>) -> std::sync::MutexGuard<'a, Slot<T>> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, Slot<T>>,
) -> std::sync::MutexGuard<'a, Slot<T>> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: std::sync::MutexGuard<'a, Slot<T>>,
    dur: Duration,
) -> std::sync::MutexGuard<'a, Slot<T>> {
    // Spurious wakeups and the timed-out flag are both handled by the
    // caller's loop re-checking the slot and its own deadline.
    match cv.wait_timeout(guard, dur) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ping_pong_roundtrip() {
        let (cmd_tx, cmd_rx) = round_channel::<u64>();
        let (rep_tx, rep_rx) = round_channel::<u64>();
        let worker = std::thread::spawn(move || {
            while let Ok(x) = cmd_rx.recv() {
                if rep_tx.send(x * 2).is_err() {
                    break;
                }
            }
        });
        for i in 0..100u64 {
            cmd_tx.send(i).unwrap();
            assert_eq!(rep_rx.recv().unwrap(), i * 2);
        }
        drop(cmd_tx);
        worker.join().unwrap();
    }

    #[test]
    fn send_fails_when_receiver_dropped() {
        let (tx, rx) = round_channel::<i32>();
        drop(rx);
        assert!(tx.send(1).is_err());
    }

    #[test]
    fn recv_drains_then_fails_when_sender_dropped() {
        let (tx, rx) = round_channel::<i32>();
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Ok(7));
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn panicking_peer_unblocks_receiver() {
        let (tx, rx) = round_channel::<i32>();
        let t = std::thread::spawn(move || {
            let _hold = tx; // dropped by unwinding
            panic!("worker died");
        });
        assert!(t.join().is_err());
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn recv_timeout_times_out_on_wedged_sender() {
        let (tx, rx) = round_channel::<i32>();
        // sender alive but silent: must come back as Timeout, not hang
        let err = rx.recv_timeout(Duration::from_millis(30)).unwrap_err();
        assert_eq!(err, RecvTimeoutError::Timeout);
        // the channel survives a timeout: a late value still arrives
        tx.send(5).unwrap();
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)), Ok(5));
    }

    #[test]
    fn recv_timeout_reports_disconnect() {
        let (tx, rx) = round_channel::<i32>();
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv_timeout(Duration::from_secs(1)), Ok(7));
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (tx, rx) = round_channel::<i32>();
        tx.send(1).unwrap();
        let t = std::thread::spawn(move || {
            // second send must wait for the recv below
            tx.send(2).unwrap();
        });
        assert_eq!(rx.recv(), Ok(1));
        assert_eq!(rx.recv(), Ok(2));
        t.join().unwrap();
    }
}
