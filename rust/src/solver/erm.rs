//! High-precision reference ERM.
//!
//! Computes `w_hat = argmin phi(w)` over the *full* dataset on a single
//! machine. Every suboptimality axis in the paper's figures is measured
//! against `phi(w_hat)`, so this solver runs to far tighter tolerance
//! (1e-12 on the gradient) than anything the distributed algorithms are
//! asked to reach (1e-6).

use crate::data::Shard;
use crate::loss::Objective;
use crate::solver::newton_cg::{minimize, Composite, NewtonCgOptions, NewtonCgScratch};
use crate::Result;

/// Reference solve. Returns (w_hat, phi(w_hat)).
pub fn solve(obj: &dyn Objective, shard: &Shard) -> Result<(Vec<f64>, f64)> {
    let (d, n) = (shard.d(), shard.n());
    let mut w = vec![0.0; d];
    let mut rowbuf = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let mut scratch = NewtonCgScratch::new(d);
    let opts = NewtonCgOptions {
        grad_tol: 1e-12,
        max_newton: 100,
        cg_tol: 1e-12,
        cg_max_iters: 4 * d.max(100),
        ..Default::default()
    };
    let problem = Composite { obj, shard, c: None, mu: 0.0, w0: None };
    minimize(&problem, &mut w, &opts, &mut rowbuf, &mut weights, &mut scratch)?;
    let value = obj.value(shard, &w, &mut rowbuf);
    Ok((w, value))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Shard;
    use crate::linalg::{ops, CholeskyFactor, DataMatrix};
    use crate::loss::testutil::{class_shard, reg_shard};
    use crate::loss::{Logistic, Ridge, SmoothHinge};

    #[test]
    fn ridge_matches_normal_equations() {
        let shard = reg_shard(100, 10, 21);
        let lam = 0.07;
        let (w, _) = solve(&Ridge::new(lam), &shard).unwrap();

        // normal equations: ((1/n) X^T X + lam I) w = (1/n) X^T y
        let x = shard.x.to_dense();
        let mut gram = x.gram();
        for i in 0..10 {
            for j in 0..10 {
                let v = gram.get(i, j) / 100.0;
                gram.set(i, j, v);
            }
        }
        let h = gram.add_diag(lam);
        let mut xty = vec![0.0; 10];
        x.rmatvec(&shard.y, &mut xty);
        ops::scale(1.0 / 100.0, &mut xty);
        let w_ref = CholeskyFactor::factor(&h).unwrap().solve(&xty);
        for j in 0..10 {
            assert!((w[j] - w_ref[j]).abs() < 1e-8, "{} vs {}", w[j], w_ref[j]);
        }
    }

    #[test]
    fn hinge_gradient_vanishes() {
        let shard = class_shard(120, 8, 33);
        let obj = SmoothHinge::new(0.01);
        let (w, v) = solve(&obj, &shard).unwrap();
        let mut g = vec![0.0; 8];
        let mut rb = vec![0.0; 120];
        let v2 = obj.value_grad(&shard, &w, &mut g, &mut rb);
        assert!(ops::norm2(&g) < 1e-10);
        assert!((v - v2).abs() < 1e-14);
    }

    #[test]
    fn logistic_gradient_vanishes() {
        let shard = class_shard(90, 5, 44);
        let obj = Logistic::new(0.02);
        let (w, _) = solve(&obj, &shard).unwrap();
        let mut g = vec![0.0; 5];
        let mut rb = vec![0.0; 90];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        assert!(ops::norm2(&g) < 1e-10);
    }

    #[test]
    fn value_is_global_minimum() {
        let shard = reg_shard(50, 4, 5);
        let obj = Ridge::new(0.1);
        let (w, v) = solve(&obj, &shard).unwrap();
        let mut rb = vec![0.0; 50];
        for k in 0..4 {
            let mut w2 = w.clone();
            w2[k] += 0.01;
            assert!(obj.value(&shard, &w2, &mut rb) > v);
        }
    }

    #[test]
    fn works_on_sparse_shards() {
        let x = crate::linalg::CsrMatrix::from_triplets(
            6,
            4,
            &[
                (0, 0, 1.0),
                (1, 1, 1.0),
                (2, 2, 1.0),
                (3, 3, 1.0),
                (4, 0, -1.0),
                (5, 2, 0.5),
            ],
        );
        let y = vec![1.0, -1.0, 1.0, -1.0, -1.0, 1.0];
        let shard = Shard::new(DataMatrix::Sparse(x), y);
        let obj = SmoothHinge::new(0.1);
        let (w, _) = solve(&obj, &shard).unwrap();
        let mut g = vec![0.0; 4];
        let mut rb = vec![0.0; 6];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        assert!(ops::norm2(&g) < 1e-10);
    }
}
