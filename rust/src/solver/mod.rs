//! Local and reference solvers.
//!
//! [`newton_cg`] is the workhorse minimizer for every composite problem
//! the system produces — DANE local steps (paper eq. 13), ADMM proximal
//! subproblems, per-machine ERMs for one-shot averaging, and the
//! high-precision reference minimizer `erm::solve` that anchors every
//! suboptimality axis in the figures.

pub mod erm;
pub mod newton_cg;

pub use erm::solve as erm_solve;
pub use newton_cg::{minimize, Composite, NewtonCgOptions, NewtonCgReport, NewtonCgScratch};
