//! Damped Newton-CG on composite shard objectives.
//!
//! Minimizes
//!
//! ```text
//! h(w) = phi(w) - c^T w + (mu/2) ||w - w0||^2
//! ```
//!
//! where `phi` is a shard [`Objective`]. This single composite covers:
//!
//! * DANE local problems (paper eq. 13): `c = grad phi_i(w') - eta g`,
//!   `w0 = w'`;
//! * ADMM proximal steps: `c = 0`, `mu = rho`, `w0 = z - u_i`;
//! * local/global ERM: `c = 0`, `mu = 0`.
//!
//! Each Newton step solves `(Hess phi(w) + mu I) delta = grad h(w)` by CG
//! over the Hessian-free [`ShardHvp`] operator (O(nnz) per iteration, no
//! Hessian materialized — mirroring `hinge_local_solve` in the L2 jax
//! model), then Armijo-backtracks on h. For quadratic phi the first full
//! step is exact and the loop exits immediately.

use crate::data::Shard;
use crate::linalg::cg::{cg_solve, CgScratch};
use crate::linalg::ops;
use crate::loss::{Objective, ShardHvp};
use crate::{Error, Result};

/// The composite problem description (borrowed pieces; cheap to build).
pub struct Composite<'a> {
    pub obj: &'a dyn Objective,
    pub shard: &'a Shard,
    /// Linear tilt `-c^T w` (None = no tilt).
    pub c: Option<&'a [f64]>,
    /// Proximal weight mu >= 0.
    pub mu: f64,
    /// Proximal center w0 (required when mu > 0).
    pub w0: Option<&'a [f64]>,
}

impl Composite<'_> {
    /// h(w) and grad h(w) in one pass; returns h, writes grad into `g`.
    pub fn value_grad(&self, w: &[f64], g: &mut [f64], rowbuf: &mut [f64]) -> f64 {
        let mut h = self.obj.value_grad(self.shard, w, g, rowbuf);
        if let Some(c) = self.c {
            h -= ops::dot(c, w);
            ops::axpy(-1.0, c, g);
        }
        if self.mu > 0.0 {
            let w0 = self.w0.expect("mu > 0 requires w0");
            let mut sq = 0.0;
            for j in 0..w.len() {
                let dj = w[j] - w0[j];
                sq += dj * dj;
                g[j] += self.mu * dj;
            }
            h += 0.5 * self.mu * sq;
        }
        h
    }

    /// h(w) only.
    pub fn value(&self, w: &[f64], rowbuf: &mut [f64]) -> f64 {
        let mut h = self.obj.value(self.shard, w, rowbuf);
        if let Some(c) = self.c {
            h -= ops::dot(c, w);
        }
        if self.mu > 0.0 {
            let w0 = self.w0.expect("mu > 0 requires w0");
            h += 0.5 * self.mu * ops::dist2(w, w0).powi(2);
        }
        h
    }
}

/// Options for [`minimize`].
#[derive(Debug, Clone, Copy)]
pub struct NewtonCgOptions {
    /// Stop when ||grad h|| <= grad_tol.
    pub grad_tol: f64,
    pub max_newton: usize,
    pub cg_tol: f64,
    pub cg_max_iters: usize,
    /// Armijo sufficient-decrease constant.
    pub armijo_c: f64,
    pub max_halvings: usize,
}

impl Default for NewtonCgOptions {
    fn default() -> Self {
        NewtonCgOptions {
            grad_tol: 1e-10,
            max_newton: 50,
            cg_tol: 1e-10,
            cg_max_iters: 500,
            armijo_c: 1e-4,
            max_halvings: 40,
        }
    }
}

/// What happened during a [`minimize`] call.
#[derive(Debug, Clone, Copy, Default)]
pub struct NewtonCgReport {
    pub newton_steps: usize,
    pub cg_iters_total: usize,
    pub final_grad_norm: f64,
    pub final_value: f64,
    pub converged: bool,
}

/// All d-sized scratch a [`minimize`] call needs, owned by the caller so
/// steady-state Newton-CG solves (the non-quadratic DANE local path)
/// allocate nothing: gradient, step direction, line-search probe, and
/// the CG work vectors. Buffers resize lazily on a dimension change.
#[derive(Debug, Clone)]
pub struct NewtonCgScratch {
    pub cg: CgScratch,
    g: Vec<f64>,
    delta: Vec<f64>,
    w_try: Vec<f64>,
}

impl NewtonCgScratch {
    pub fn new(d: usize) -> Self {
        NewtonCgScratch {
            cg: CgScratch::new(d),
            g: vec![0.0; d],
            delta: vec![0.0; d],
            w_try: vec![0.0; d],
        }
    }

    fn ensure(&mut self, d: usize) {
        if self.g.len() != d {
            self.g.resize(d, 0.0);
            self.delta.resize(d, 0.0);
            self.w_try.resize(d, 0.0);
        }
    }
}

/// Minimize the composite from `w` (overwritten with the minimizer).
///
/// Scratch: `rowbuf` (len n), `weights` (len n), `scratch` reusable
/// across calls (no per-call allocation once sized). Returns the report;
/// errors only on CG breakdown (non-convex curvature, which cannot
/// happen for the convex objectives in this crate) or shape bugs.
pub fn minimize(
    problem: &Composite<'_>,
    w: &mut [f64],
    opts: &NewtonCgOptions,
    rowbuf: &mut [f64],
    weights: &mut [f64],
    scratch: &mut NewtonCgScratch,
) -> Result<NewtonCgReport> {
    let d = w.len();
    let n = problem.shard.n();
    if rowbuf.len() != n || weights.len() != n {
        return Err(Error::Shape(format!(
            "newton_cg scratch: rowbuf {} weights {} want n {n}",
            rowbuf.len(),
            weights.len()
        )));
    }
    scratch.ensure(d);
    let NewtonCgScratch { cg, g, delta, w_try } = scratch;
    let mut report = NewtonCgReport::default();

    let mut h = problem.value_grad(w, g, rowbuf);
    loop {
        let gnorm = ops::norm2(g);
        report.final_grad_norm = gnorm;
        report.final_value = h;
        if gnorm <= opts.grad_tol {
            report.converged = true;
            return Ok(report);
        }
        if report.newton_steps >= opts.max_newton {
            return Ok(report);
        }
        report.newton_steps += 1;

        // (Hess phi(w) + mu I) delta = g
        problem.obj.hess_weights(problem.shard, w, weights);
        let reg = problem.obj.lambda() + problem.mu;
        let hvp = ShardHvp::new(problem.shard, weights, reg);
        let out = cg_solve(&hvp, g, delta, opts.cg_tol, opts.cg_max_iters, cg)?;
        report.cg_iters_total += out.iters;

        // Backtrack: w_try = w - s * delta until Armijo holds.
        let slope = ops::dot(g, delta); // descent: slope > 0 since H SPD
        let mut s = 1.0;
        let mut accepted = false;
        for _ in 0..=opts.max_halvings {
            for j in 0..d {
                w_try[j] = w[j] - s * delta[j];
            }
            let h_try = problem.value(w_try, rowbuf);
            if h_try <= h - opts.armijo_c * s * slope {
                w.copy_from_slice(w_try);
                accepted = true;
                break;
            }
            s *= 0.5;
        }
        if !accepted {
            // Step direction exhausted to machine precision: we are at
            // (numerical) optimality — report and stop.
            return Ok(report);
        }
        h = problem.value_grad(w, g, rowbuf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::{class_shard, reg_shard};
    use crate::loss::{Ridge, SmoothHinge};

    fn run(problem: &Composite<'_>, d: usize, n: usize) -> (Vec<f64>, NewtonCgReport) {
        let mut w = vec![0.0; d];
        let mut rowbuf = vec![0.0; n];
        let mut weights = vec![0.0; n];
        let mut scratch = NewtonCgScratch::new(d);
        let rep = minimize(
            problem,
            &mut w,
            &NewtonCgOptions::default(),
            &mut rowbuf,
            &mut weights,
            &mut scratch,
        )
        .unwrap();
        (w, rep)
    }

    #[test]
    fn quadratic_converges_in_one_newton_step() {
        let shard = reg_shard(50, 8, 4);
        let obj = Ridge::new(0.1);
        let p = Composite { obj: &obj, shard: &shard, c: None, mu: 0.0, w0: None };
        let (w, rep) = run(&p, 8, 50);
        assert_eq!(rep.newton_steps, 1, "{rep:?}");
        assert!(rep.converged);
        // gradient at the solution vanishes
        let mut g = vec![0.0; 8];
        let mut rb = vec![0.0; 50];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        assert!(ops::norm2(&g) < 1e-9);
    }

    #[test]
    fn hinge_erm_reaches_stationarity() {
        let shard = class_shard(80, 6, 9);
        let obj = SmoothHinge::new(0.05);
        let p = Composite { obj: &obj, shard: &shard, c: None, mu: 0.0, w0: None };
        let (_w, rep) = run(&p, 6, 80);
        assert!(rep.converged, "{rep:?}");
        assert!(rep.final_grad_norm < 1e-10);
    }

    #[test]
    fn tilt_shifts_the_optimum() {
        // min phi(w) - c^T w has gradient phi'(w) = c at the optimum.
        let shard = reg_shard(40, 5, 2);
        let obj = Ridge::new(0.2);
        let c = vec![0.3, -0.1, 0.0, 0.2, -0.4];
        let p = Composite { obj: &obj, shard: &shard, c: Some(&c), mu: 0.0, w0: None };
        let (w, rep) = run(&p, 5, 40);
        assert!(rep.converged);
        let mut g = vec![0.0; 5];
        let mut rb = vec![0.0; 40];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        for j in 0..5 {
            assert!((g[j] - c[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn proximal_term_pulls_toward_center() {
        let shard = class_shard(30, 4, 6);
        let obj = SmoothHinge::new(0.01);
        let w0 = vec![5.0, -5.0, 5.0, -5.0];
        let free = Composite { obj: &obj, shard: &shard, c: None, mu: 0.0, w0: None };
        let prox = Composite { obj: &obj, shard: &shard, c: None, mu: 100.0, w0: Some(&w0) };
        let (wf, _) = run(&free, 4, 30);
        let (wp, _) = run(&prox, 4, 30);
        // with huge mu, the prox solution must be much closer to w0
        assert!(ops::dist2(&wp, &w0) < 0.5 * ops::dist2(&wf, &w0));
    }

    #[test]
    fn dane_identity_m1() {
        // With one machine phi_i = phi, so the DANE tilt (paper eq. 13)
        // is c = grad phi_i(w') - eta grad phi(w') = (1-eta) grad phi(w').
        // The tilted optimum satisfies grad phi(w) = c; for eta = 1 the
        // tilt vanishes and the local solve lands on the global ERM.
        let shard = reg_shard(60, 7, 12);
        let obj = Ridge::new(0.05);
        // ERM reference
        let erm = Composite { obj: &obj, shard: &shard, c: None, mu: 0.0, w0: None };
        let (w_star, _) = run(&erm, 7, 60);
        // DANE local from arbitrary w', with the tilt built explicitly
        let wp: Vec<f64> = (0..7).map(|i| (i as f64) * 0.3 - 1.0).collect();
        let mut g = vec![0.0; 7];
        let mut rb = vec![0.0; 60];
        obj.value_grad(&shard, &wp, &mut g, &mut rb);
        for &eta in &[1.0, 0.5] {
            let c: Vec<f64> = g.iter().map(|gi| (1.0 - eta) * gi).collect();
            let p = Composite { obj: &obj, shard: &shard, c: Some(&c), mu: 0.0, w0: None };
            let (w1, rep) = run(&p, 7, 60);
            assert!(rep.converged, "eta={eta}: {rep:?}");
            // stationarity of the tilted problem: grad phi(w1) = c
            let mut g1 = vec![0.0; 7];
            obj.value_grad(&shard, &w1, &mut g1, &mut rb);
            for j in 0..7 {
                assert!(
                    (g1[j] - c[j]).abs() < 1e-8,
                    "eta={eta} j={j}: {} vs {}",
                    g1[j],
                    c[j]
                );
            }
            if eta == 1.0 {
                // eta = 1 makes the one-machine DANE step exactly ERM
                for j in 0..7 {
                    assert!((w1[j] - w_star[j]).abs() < 1e-8);
                }
            } else {
                // a genuine tilt moves the optimum off the ERM point
                assert!(ops::dist2(&w1, &w_star) > 1e-6);
            }
        }
    }
}
