//! f64-slice <-> f32 PJRT literal marshalling.
//!
//! The optimization stack is f64 end to end (conditioning of the paper's
//! small-lambda regimes demands it); the AOT artifacts are f32 (the TPU
//! target's natural width). Conversions happen only at the PJRT boundary;
//! the native/pjrt agreement tests pin the acceptable drift.

use crate::xla;
use crate::{Error, Result};

/// Build a rank-1 f32 literal from an f64 slice.
pub fn vec_literal(v: &[f64]) -> xla::Literal {
    let f32s: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f32s)
}

/// Build a rank-2 (rows x cols) f32 literal from a row-major f64 slice.
pub fn mat_literal(data: &[f64], rows: usize, cols: usize) -> Result<xla::Literal> {
    if data.len() != rows * cols {
        return Err(Error::Shape(format!(
            "mat_literal: {} values for {rows}x{cols}",
            data.len()
        )));
    }
    let f32s: Vec<f32> = data.iter().map(|&x| x as f32).collect();
    Ok(xla::Literal::vec1(&f32s).reshape(&[rows as i64, cols as i64])?)
}

/// Rank-0 f32 scalar literal.
pub fn scalar_literal(x: f64) -> xla::Literal {
    xla::Literal::scalar(x as f32)
}

/// Read a rank-1 (or rank-0) f32 literal back into f64.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    let f32s: Vec<f32> = lit.to_vec()?;
    Ok(f32s.into_iter().map(f64::from).collect())
}

/// Read a single f32 element (rank-0 or length-1) literal.
pub fn literal_to_scalar(lit: &xla::Literal) -> Result<f64> {
    let v = literal_to_vec(lit)?;
    v.first().copied().ok_or_else(|| {
        Error::Runtime("expected scalar literal, got empty".into())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_roundtrip() {
        let v = vec![1.5, -2.25, 0.0];
        let lit = vec_literal(&v);
        assert_eq!(literal_to_vec(&lit).unwrap(), v);
    }

    #[test]
    fn mat_shape_checked() {
        assert!(mat_literal(&[1.0, 2.0, 3.0], 2, 2).is_err());
        let lit = mat_literal(&[1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_literal(3.5);
        assert_eq!(literal_to_scalar(&lit).unwrap(), 3.5);
    }

    #[test]
    fn f32_quantization_is_expected() {
        let v = vec![1.0 + 1e-12];
        let lit = vec_literal(&v);
        let back = literal_to_vec(&lit).unwrap();
        assert_eq!(back[0], 1.0); // dropped below f32 resolution
    }
}
