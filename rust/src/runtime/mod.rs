//! PJRT runtime bridge: load `artifacts/*.hlo.txt`, compile once on the
//! CPU PJRT client, execute from the rust hot path.
//!
//! The interchange contract (DESIGN.md §10): HLO *text* (jax >= 0.5 protos
//! carry 64-bit ids the image's xla_extension 0.5.1 rejects; the text
//! parser reassigns them), `return_tuple=True` on every entry, f32
//! throughout, shapes specialized to the manifest's canonical shards.
//! Rust zero-pads each worker's shard to the artifact shape once at
//! session construction; padded rows carry x = 0, y = 0 and contribute
//! nothing to any output (tested both in pytest and here).

pub mod artifact;
pub mod client;
pub mod literal;

pub use artifact::{ArtifactRegistry, Manifest, ManifestEntry};
pub use client::PjrtSession;
