//! Manifest-driven artifact registry.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing
//! every lowered entry point (name, file, input shapes, output arity,
//! static shard shape). The registry parses the manifest (through the
//! in-tree JSON layer), compiles each HLO text module on the shared PJRT
//! CPU client **lazily** (first use), and memoizes the loaded
//! executables — one compile per (entry, shape) per process.

use crate::util::Json;
use crate::xla;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One tensor's shape/dtype in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Static shard shape an entry was specialized to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StaticShape {
    pub n: usize,
    pub d: usize,
}

/// One lowered entry point.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
    pub static_shape: StaticShape,
    pub sha256: String,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub format: String,
    pub return_tuple: bool,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let format = v.req("format")?.as_str().unwrap_or_default().to_string();
        let return_tuple = v.req("return_tuple")?.as_bool().unwrap_or(false);
        let mut entries = Vec::new();
        for e in v
            .req("entries")?
            .as_array()
            .ok_or_else(|| Error::Runtime("manifest entries must be an array".into()))?
        {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let file = e.req("file")?.as_str().unwrap_or_default().to_string();
            let n_outputs = e
                .req("n_outputs")?
                .as_usize()
                .ok_or_else(|| Error::Runtime(format!("{name}: bad n_outputs")))?;
            let st = e.req("static")?;
            let static_shape = StaticShape {
                n: st.req("n")?.as_usize().unwrap_or(0),
                d: st.req("d")?.as_usize().unwrap_or(0),
            };
            let mut inputs = Vec::new();
            if let Some(arr) = e.get("inputs").and_then(|x| x.as_array()) {
                for spec in arr {
                    let shape = spec
                        .get("shape")
                        .and_then(|s| s.as_array())
                        .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                        .unwrap_or_default();
                    let dtype = spec
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("f32")
                        .to_string();
                    inputs.push(TensorSpec { shape, dtype });
                }
            }
            let sha256 = e
                .get("sha256")
                .and_then(|x| x.as_str())
                .unwrap_or_default()
                .to_string();
            entries.push(ManifestEntry {
                name,
                file,
                inputs,
                n_outputs,
                static_shape,
                sha256,
            });
        }
        Ok(Manifest { format, return_tuple, entries })
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let m = Self::parse(&text)?;
        if m.format != "hlo-text" {
            return Err(Error::Runtime(format!(
                "unsupported artifact format {:?}",
                m.format
            )));
        }
        if !m.return_tuple {
            return Err(Error::Runtime(
                "manifest must declare return_tuple=true".into(),
            ));
        }
        Ok(m)
    }

    /// The smallest shard shape of family `family` that fits (n, d).
    /// Entries are named `{family}_n{n}_d{d}` by aot.py.
    pub fn fit_shape(&self, family: &str, n: usize, d: usize) -> Option<StaticShape> {
        let prefix = format!("{family}_n");
        let mut best: Option<StaticShape> = None;
        for e in &self.entries {
            if !e.name.starts_with(&prefix) {
                continue;
            }
            let s = e.static_shape;
            if s.n >= n && s.d >= d {
                let better = match best {
                    None => true,
                    Some(b) => (s.n * s.d) < (b.n * b.d),
                };
                if better {
                    best = Some(s);
                }
            }
        }
        best
    }
}

/// Compiled-executable registry over one PJRT client.
pub struct ArtifactRegistry {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ArtifactRegistry {
    /// Open the registry over `dir` (usually `artifacts/`). Compiles
    /// nothing yet.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.manifest
            .entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                Error::Runtime(format!("no artifact entry named {name:?}"))
            })
    }

    /// The smallest canonical shard shape that fits (n, d) for `family`.
    pub fn fit_shape(&self, family: &str, n: usize, d: usize) -> Result<StaticShape> {
        self.manifest.fit_shape(family, n, d).ok_or_else(|| {
            Error::Runtime(format!(
                "no {family} artifact fits shard {n}x{d}; re-run aot.py with a larger shape"
            ))
        })
    }

    /// Get (compiling on first use) the executable for an entry name.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        {
            let map = self.compiled.lock().unwrap();
            if let Some(exe) = map.get(name) {
                return Ok(exe.clone());
            }
        }
        let entry = self.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-utf8 path {}", path.display()))
            })?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        let mut map = self.compiled.lock().unwrap();
        Ok(map.entry(name.to_string()).or_insert(exe).clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_json() -> &'static str {
        r#"{
          "format": "hlo-text",
          "return_tuple": true,
          "entries": [
            {"name": "ridge_grad_n256_d64", "file": "ridge_grad_n256_d64.hlo.txt",
             "inputs": [{"shape": [256, 64], "dtype": "f32"}],
             "n_outputs": 2, "static": {"n": 256, "d": 64}},
            {"name": "ridge_grad_n2048_d512", "file": "ridge_grad_n2048_d512.hlo.txt",
             "inputs": [{"shape": [2048, 512], "dtype": "f32"}],
             "n_outputs": 2, "static": {"n": 2048, "d": 512}}
          ]
        }"#
    }

    #[test]
    fn manifest_parses() {
        let m = Manifest::parse(manifest_json()).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].static_shape, StaticShape { n: 256, d: 64 });
        assert_eq!(m.entries[0].inputs[0].shape, vec![256, 64]);
        assert!(m.return_tuple);
    }

    #[test]
    fn fit_shape_picks_smallest_fitting() {
        let m = Manifest::parse(manifest_json()).unwrap();
        assert_eq!(
            m.fit_shape("ridge_grad", 100, 50),
            Some(StaticShape { n: 256, d: 64 })
        );
        assert_eq!(
            m.fit_shape("ridge_grad", 300, 64),
            Some(StaticShape { n: 2048, d: 512 })
        );
        assert_eq!(m.fit_shape("ridge_grad", 5000, 64), None);
        assert_eq!(m.fit_shape("hinge_grad_loss", 10, 10), None);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"format": "hlo-text"}"#).is_err());
        assert!(Manifest::parse("[]").is_err());
    }
}
