//! Per-worker PJRT session: padded shard buffers + typed entry wrappers.
//!
//! A [`PjrtSession`] is created once per worker. It pads the worker's
//! shard to the smallest canonical artifact shape (zero rows / zero
//! labels are provably inert — see the loss modules and pytest), uploads
//! the shard literals once, and then serves the two hot-path calls:
//! gradient(+loss) and the DANE local solve. Hyperparameters travel as
//! rank-0 literals, so the same compiled executable serves every
//! (eta, mu, lam) setting.

use super::artifact::ArtifactRegistry;
use super::literal::{literal_to_scalar, literal_to_vec, mat_literal, scalar_literal, vec_literal};
use crate::data::Shard;
use crate::xla;
use crate::loss::Objective;
use crate::{Error, Result};
use std::sync::Arc;

/// Which artifact family a loss maps to.
fn families_for(obj: &dyn Objective) -> Result<(&'static str, &'static str)> {
    match obj.name() {
        "ridge" => Ok(("ridge_grad", "ridge_local_solve")),
        "smooth_hinge" => Ok(("hinge_grad_loss", "hinge_local_solve")),
        other => Err(Error::Runtime(format!(
            "no AOT artifacts for loss {other:?} (native backend only)"
        ))),
    }
}

/// One worker's handle onto the artifact registry.
pub struct PjrtSession {
    registry: Arc<ArtifactRegistry>,
    /// Padded shard literals, uploaded once.
    x_lit: xla::Literal,
    y_lit: xla::Literal,
    n_pad: usize,
    d_pad: usize,
    n_eff: usize,
    d: usize,
}

impl PjrtSession {
    /// Build a session for one shard. Picks the smallest artifact shape
    /// that fits and pads the shard into it.
    pub fn for_shard(
        registry: Arc<ArtifactRegistry>,
        shard: &Shard,
        obj: &dyn Objective,
    ) -> Result<Self> {
        let (grad_family, _) = families_for(obj)?;
        let fit = registry.fit_shape(grad_family, shard.n(), shard.d())?;
        let (n_pad, d_pad) = (fit.n, fit.d);

        // Pad row-major X into (n_pad, d_pad); padding stays zero. Rows
        // stream straight from the shard's own representation — never
        // densify the whole matrix first (a sparse shard would briefly
        // hold two full dense copies).
        let mut xbuf = vec![0.0f64; n_pad * d_pad];
        match &shard.x {
            crate::linalg::DataMatrix::Dense(m) => {
                for i in 0..shard.n() {
                    xbuf[i * d_pad..i * d_pad + shard.d()].copy_from_slice(m.row(i));
                }
            }
            crate::linalg::DataMatrix::Sparse(s) => {
                for i in 0..shard.n() {
                    let (idx, val) = s.row(i);
                    for (&j, &v) in idx.iter().zip(val) {
                        xbuf[i * d_pad + j as usize] = v;
                    }
                }
            }
        }
        let mut ybuf = vec![0.0f64; n_pad];
        ybuf[..shard.n()].copy_from_slice(&shard.y);

        Ok(PjrtSession {
            registry,
            x_lit: mat_literal(&xbuf, n_pad, d_pad)?,
            y_lit: vec_literal(&ybuf),
            n_pad,
            d_pad,
            n_eff: shard.n_effective(),
            d: shard.d(),
        })
    }

    fn entry_name(&self, family: &str) -> String {
        format!("{family}_n{}_d{}", self.n_pad, self.d_pad)
    }

    /// Pad a d-vector to d_pad.
    fn pad_vec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.d_pad];
        out[..v.len()].copy_from_slice(v);
        out
    }

    /// grad phi_i(w) into `out`; returns phi_i(w).
    pub fn grad(
        &self,
        _shard: &Shard,
        obj: &dyn Objective,
        w: &[f64],
        out: &mut [f64],
    ) -> Result<f64> {
        let (grad_family, _) = families_for(obj)?;
        let exe = self.registry.executable(&self.entry_name(grad_family))?;
        let w_lit = vec_literal(&self.pad_vec(w));
        let lam = scalar_literal(obj.lambda());
        let ninv = scalar_literal(1.0 / self.n_eff as f64);
        let args: [&xla::Literal; 5] = [&self.x_lit, &self.y_lit, &w_lit, &lam, &ninv];
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let (g_lit, loss_lit) = result.to_tuple2()?;
        let g = literal_to_vec(&g_lit)?;
        out.copy_from_slice(&g[..self.d]);
        literal_to_scalar(&loss_lit)
    }

    /// DANE local solve (paper eq. 13/16) through the AOT artifact.
    pub fn dane_local_solve(
        &self,
        _shard: &Shard,
        obj: &dyn Objective,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let (_, solve_family) = families_for(obj)?;
        let exe = self.registry.executable(&self.entry_name(solve_family))?;
        let wp = vec_literal(&self.pad_vec(w_prev));
        let gl = vec_literal(&self.pad_vec(g));
        let eta_l = scalar_literal(eta);
        let mu_l = scalar_literal(mu);
        let lam = scalar_literal(obj.lambda());
        let ninv = scalar_literal(1.0 / self.n_eff as f64);
        // ridge_local_solve(x, w_prev, g, eta, mu, lam, ninv)
        // hinge_local_solve(x, y, w_prev, g, eta, mu, lam, ninv)
        let args: Vec<&xla::Literal> = if solve_family == "ridge_local_solve" {
            vec![&self.x_lit, &wp, &gl, &eta_l, &mu_l, &lam, &ninv]
        } else {
            vec![&self.x_lit, &self.y_lit, &wp, &gl, &eta_l, &mu_l, &lam, &ninv]
        };
        let result = exe.execute::<&xla::Literal>(&args)?[0][0].to_literal_sync()?;
        let w_lit = result.to_tuple1()?;
        let w = literal_to_vec(&w_lit)?;
        Ok(w[..self.d].to_vec())
    }

    /// Padded shape diagnostics.
    pub fn padded_shape(&self) -> (usize, usize) {
        (self.n_pad, self.d_pad)
    }
}
