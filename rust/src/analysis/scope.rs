//! `#[cfg(test)]` / `#[test]` scope tracking over masked source.
//!
//! The panic-freedom and densify rules exempt test code: a `#[cfg(test)]
//! mod tests { … }` block at the bottom of a production file (the
//! repo-wide convention) may unwrap freely. This tracker computes, per
//! line, whether the line sits inside an item that a test-shaped
//! attribute guards.
//!
//! The model is purely lexical but exact for the shapes this repo uses:
//! after a `#[cfg(test)]`-like or `#[test]` attribute, the next `{ … }`
//! block (the guarded item's body) is a test region, tracked to its
//! matching close brace; a `;` before any `{` ends the item without a
//! body (`#[cfg(test)] use …;`). Regions nest — an inner attribute
//! never un-tests an outer region.

/// Per-line test flags for masked code: `flags[line - 1]` is true when
/// 1-based `line` is inside (or on the braces of) a test-scoped item.
pub fn test_lines(code: &str) -> Vec<bool> {
    let n_lines = code.matches('\n').count() + 1;
    let mut flags = vec![false; n_lines];
    let b = code.as_bytes();
    let mut line = 1usize;
    let mut depth = 0usize;
    // brace depths at which an active test region closes
    let mut regions: Vec<usize> = Vec::new();
    // a test attribute was seen and its item body not yet opened
    let mut pending = false;
    let mut i = 0usize;

    while i < b.len() {
        match b[i] {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b'#' => {
                // #[…] or #![…]: scan the bracket group, decide if it
                // is a test-shaped attribute
                let mut j = i + 1;
                if j < b.len() && b[j] == b'!' {
                    j += 1;
                }
                if j < b.len() && b[j] == b'[' {
                    let start = j + 1;
                    let mut brackets = 1usize;
                    let mut k = start;
                    while k < b.len() && brackets > 0 {
                        match b[k] {
                            b'[' => brackets += 1,
                            b']' => brackets -= 1,
                            b'\n' => line += 1,
                            _ => {}
                        }
                        k += 1;
                    }
                    let end = k.saturating_sub(1).max(start);
                    let attr = code.get(start..end).unwrap_or("");
                    if attr_is_test(attr) {
                        pending = true;
                        if !regions.is_empty() {
                            mark(&mut flags, line);
                        }
                    }
                    i = k;
                } else {
                    i += 1;
                }
                continue;
            }
            b';' if pending && regions.is_empty() => {
                // bodiless guarded item (`#[cfg(test)] use …;`)
                pending = false;
                i += 1;
            }
            b'{' => {
                depth += 1;
                if pending {
                    regions.push(depth);
                    pending = false;
                }
                if !regions.is_empty() {
                    mark(&mut flags, line);
                }
                i += 1;
            }
            b'}' => {
                if !regions.is_empty() {
                    mark(&mut flags, line);
                }
                if regions.last() == Some(&depth) {
                    regions.pop();
                }
                depth = depth.saturating_sub(1);
                i += 1;
            }
            _ => {
                if !regions.is_empty() {
                    mark(&mut flags, line);
                }
                i += 1;
            }
        }
    }
    flags
}

fn mark(flags: &mut [bool], line: usize) {
    if let Some(f) = flags.get_mut(line - 1) {
        *f = true;
    }
}

/// Does attribute text (the part inside `#[…]`) guard test-only code?
/// Matches `test`, `cfg(test)`, `cfg(all(test, …))`, `tokio::test`, …:
/// the word `test` must appear with identifier boundaries, and the
/// attribute must be either a bare `…test` path or a `cfg(…)`.
fn attr_is_test(attr: &str) -> bool {
    let t = attr.trim();
    let has_test_word = {
        let bytes = t.as_bytes();
        let mut found = false;
        let mut i = 0;
        while let Some(off) = t[i..].find("test") {
            let s = i + off;
            let before_ok = s == 0 || !is_ident_byte(bytes[s - 1]);
            let after = s + 4;
            let after_ok = after >= bytes.len() || !is_ident_byte(bytes[after]);
            if before_ok && after_ok {
                found = true;
                break;
            }
            i = s + 1;
        }
        found
    };
    has_test_word && (t.starts_with("cfg") || t.ends_with("test"))
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flags(src: &str) -> Vec<bool> {
        test_lines(&super::super::lexer::mask(src).code)
    }

    #[test]
    fn cfg_test_mod_is_scoped_to_its_braces() {
        let src = "fn prod() {\n    work();\n}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn prod2() {}\n";
        let f = flags(src);
        assert!(!f[0] && !f[1] && !f[2], "production code untouched");
        assert!(f[4] && f[5] && f[6], "mod tests body is test scope");
        assert!(!f[7], "code after the close brace is production again");
    }

    #[test]
    fn test_fn_attribute_scopes_one_function() {
        let src = "#[test]\nfn t() {\n    x.unwrap();\n}\nfn prod() {}\n";
        let f = flags(src);
        assert!(f[1] && f[2] && f[3]);
        assert!(!f[4]);
    }

    #[test]
    fn cfg_all_test_counts_and_bodiless_items_do_not_leak() {
        let src = "#[cfg(all(test, unix))]\nuse foo::bar;\nfn prod() {\n    x();\n}\n";
        let f = flags(src);
        assert!(!f[2] && !f[3], "`;` must cancel the pending attribute");
    }

    #[test]
    fn non_test_attrs_do_not_open_regions() {
        let src = "#[cfg(unix)]\nfn prod() {\n    x.unwrap();\n}\n#[derive(Debug)]\nstruct S {\n    a: u8,\n}\n";
        let f = flags(src);
        assert!(f.iter().all(|&x| !x), "no test scope anywhere: {f:?}");
    }

    #[test]
    fn testutil_like_words_do_not_match() {
        // `attest`, `testing`… must not read as the word `test`
        assert!(!attr_is_test("cfg(feature = \"attest\")"));
        assert!(attr_is_test("cfg(test)"));
        assert!(attr_is_test("test"));
        assert!(attr_is_test("tokio::test"));
        assert!(!attr_is_test("derive(Debug)"));
    }

    #[test]
    fn nested_braces_inside_test_mod_stay_test() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() {\n        if x { y.unwrap(); }\n    }\n}\n";
        let f = flags(src);
        assert!(f[2] && f[3] && f[4]);
    }
}
