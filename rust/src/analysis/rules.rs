//! The five `dane-lint` rules.
//!
//! Each rule is a plain function from [`FileAnalysis`] (plus, for the
//! cross-reference rules, the anchor files they check against) to a
//! list of [`Diagnostic`]s, so `tests/lint_self.rs` can drive each one
//! over fixture snippets through exactly the code path CI runs. All
//! scanning is over masked code (comments/strings blanked) and 1-based
//! lines; test-scoped lines are exempt where the rule says so.

use super::{Diagnostic, FileAnalysis};

pub const PANIC_FREEDOM: &str = "panic-freedom";
pub const DENSIFY: &str = "densify";
pub const WIRE_TOTALITY: &str = "wire-totality";
pub const CSV_SCHEMA: &str = "csv-schema";
pub const DETERMINISM: &str = "determinism";
/// Pseudo-rule for misused `lint:allow` markers (malformed or stale).
pub const LINT_ALLOW: &str = "lint-allow";

/// Directories whose non-test code must be panic-free: everything a
/// worker failure or a hostile byte stream can reach.
const PANIC_SCOPES: &[&str] = &[
    "rust/src/coordinator/",
    "rust/src/comm/",
    "rust/src/worker/",
];

/// Files allowed to read wall clocks: per-round `elapsed_seconds`
/// trace timing in the algorithm drivers, the bench harness, and the
/// rendezvous channel's deadline bookkeeping. Wall time here feeds
/// *reporting*, never an iterate.
const TIME_ALLOW: &[&str] = &[
    "rust/src/comm/roundchan.rs",
    "rust/src/coordinator/admm.rs",
    "rust/src/coordinator/dane.rs",
    "rust/src/coordinator/gd.rs",
    "rust/src/coordinator/lbfgs.rs",
    "rust/src/coordinator/osa.rs",
    "rust/src/util/bench.rs",
];

/// Methods whose results inherit `HashMap`/`HashSet` iteration order.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "drain",
];

/// A test fn whose name mentions one of these counts as hostile-bytes
/// coverage for the wire-totality rule.
const HOSTILE_MARKERS: &[&str] = &["trunc", "hostile", "malformed", "corrupt", "reject"];

/// Reduction kernels that must keep the canonical 4-lane accumulator
/// structure (`linalg/ops.rs` module docs): per file, the fns whose
/// bodies must mention all of [`LANES`]. Losing the lanes silently
/// reverts a kernel to a scalar sequential fold — different bits than
/// the pinned `(a0 + a2) + (a1 + a3)` order and a 3-4x throughput loss.
const LANE_KERNELS: &[(&str, &[&str])] = &[
    ("rust/src/linalg/ops.rs", &["dot", "dist2"]),
    ("rust/src/linalg/sparse.rs", &["row_dot", "row_sq_norm"]),
];

/// The four lane accumulators of the canonical reduction fold.
const LANES: &[&str] = &["a0", "a1", "a2", "a3"];

// ---------------------------------------------------------------- tokens

/// One identifier-shaped token in masked code (byte offsets).
#[derive(Debug, Clone, Copy)]
struct Tok {
    start: usize,
    end: usize,
}

/// All identifier tokens (keywords included; numbers skipped so `0x81`
/// never yields a stray `x81`).
fn idents(code: &str) -> Vec<Tok> {
    let b = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let c = b[i];
        if c == b'_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            out.push(Tok { start: s, end: i });
        } else if c.is_ascii_digit() {
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Byte-offset → 1-based line translation.
struct Lines {
    starts: Vec<usize>,
}

impl Lines {
    fn new(code: &str) -> Lines {
        let mut starts = vec![0usize];
        for (i, b) in code.bytes().enumerate() {
            if b == b'\n' {
                starts.push(i + 1);
            }
        }
        Lines { starts }
    }

    fn line_of(&self, pos: usize) -> usize {
        match self.starts.binary_search(&pos) {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }
}

/// Previous non-whitespace byte before `pos`.
fn prev_sig(b: &[u8], mut pos: usize) -> Option<u8> {
    while pos > 0 {
        pos -= 1;
        if !b[pos].is_ascii_whitespace() {
            return Some(b[pos]);
        }
    }
    None
}

/// Next non-whitespace byte at or after `pos`.
fn next_sig(b: &[u8], mut pos: usize) -> Option<u8> {
    while pos < b.len() {
        if !b[pos].is_ascii_whitespace() {
            return Some(b[pos]);
        }
        pos += 1;
    }
    None
}

// ---------------------------------------------------------- panic-freedom

/// No `.unwrap()`, `.expect(…)`, `panic!`, `unreachable!`, `todo!` or
/// `unimplemented!` in non-test code under coordinator/, comm/, worker/.
pub fn panic_freedom(f: &FileAnalysis) -> Vec<Diagnostic> {
    if !PANIC_SCOPES.iter().any(|p| f.rel_path.starts_with(p)) {
        return Vec::new();
    }
    let b = f.code.as_bytes();
    let lines = Lines::new(&f.code);
    let mut out = Vec::new();
    for t in idents(&f.code) {
        let text = &f.code[t.start..t.end];
        let hit = match text {
            "unwrap" | "expect" => {
                prev_sig(b, t.start) == Some(b'.') && next_sig(b, t.end) == Some(b'(')
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                next_sig(b, t.end) == Some(b'!')
            }
            _ => false,
        };
        if !hit {
            continue;
        }
        let line = lines.line_of(t.start);
        if f.is_test_line(line) {
            continue;
        }
        out.push(Diagnostic {
            file: f.rel_path.clone(),
            line,
            rule: PANIC_FREEDOM,
            msg: format!(
                "`{text}` on the panic-free surface (coordinator/comm/worker): \
                 return an `Err` or route through a documented helper, or add \
                 `lint:allow(panic-freedom): <reason>`"
            ),
        });
    }
    out
}

// ---------------------------------------------------------------- densify

/// `.to_dense(` only inside linalg/ internals and test scopes: nothing
/// on the data path may materialize a dense copy of a sparse shard.
pub fn densify(f: &FileAnalysis) -> Vec<Diagnostic> {
    if f.rel_path.starts_with("rust/src/linalg/") {
        return Vec::new();
    }
    let b = f.code.as_bytes();
    let lines = Lines::new(&f.code);
    let mut out = Vec::new();
    for t in idents(&f.code) {
        if &f.code[t.start..t.end] != "to_dense" {
            continue;
        }
        if prev_sig(b, t.start) != Some(b'.') || next_sig(b, t.end) != Some(b'(') {
            continue;
        }
        let line = lines.line_of(t.start);
        if f.is_test_line(line) {
            continue;
        }
        out.push(Diagnostic {
            file: f.rel_path.clone(),
            line,
            rule: DENSIFY,
            msg: "`.to_dense()` outside linalg/ materializes a dense copy of a \
                  (possibly huge) sparse shard; operate in sparse form or move \
                  the helper into linalg/"
                .to_string(),
        });
    }
    out
}

// ------------------------------------------------------------ determinism

/// No wall clocks outside the timing allowlist, no iteration over
/// `HashMap`/`HashSet` bindings (their order is nondeterministic and
/// must never feed a numeric fold or trace output), and the hot-path
/// reduction kernels on the [`LANE_KERNELS`] allowlist must keep their
/// canonical 4-lane accumulator structure.
pub fn determinism(f: &FileAnalysis) -> Vec<Diagnostic> {
    let code = &f.code;
    let lines = Lines::new(code);
    let toks = idents(code);
    let mut out = Vec::new();

    if let Some((_, kernels)) = LANE_KERNELS.iter().find(|(p, _)| *p == f.rel_path) {
        out.extend(lane_structure(f, kernels, &lines));
    }

    if !TIME_ALLOW.contains(&f.rel_path.as_str()) {
        for (k, t) in toks.iter().enumerate() {
            let text = &code[t.start..t.end];
            // a type mention (`-> Instant`) is not a clock read; the
            // `::now` call is
            let clocked = matches!(text, "Instant" | "SystemTime")
                && followed_by_now(code, &toks, k);
            if !clocked {
                continue;
            }
            let line = lines.line_of(t.start);
            if f.is_test_line(line) {
                continue;
            }
            out.push(Diagnostic {
                file: f.rel_path.clone(),
                line,
                rule: DETERMINISM,
                msg: format!(
                    "wall-clock read (`{text}`) outside the metrics timing \
                     allowlist; clocks must never influence an iterate or a trace \
                     column other than elapsed time"
                ),
            });
        }
    }

    let suspects = hash_binding_names(code, &toks);
    if !suspects.is_empty() {
        for (k, t) in toks.iter().enumerate() {
            let text = &code[t.start..t.end];
            let line = lines.line_of(t.start);
            if f.is_test_line(line) {
                continue;
            }
            let hit_name = if text == "in" {
                loop_source_hit(code, &toks, k, &suspects)
            } else if suspects.iter().any(|s| s == text) {
                match method_after(code, &toks, k) {
                    Some(m) if ITER_METHODS.contains(&m.as_str()) => Some(text.to_string()),
                    _ => None,
                }
            } else {
                None
            };
            if let Some(name) = hit_name {
                out.push(Diagnostic {
                    file: f.rel_path.clone(),
                    line,
                    rule: DETERMINISM,
                    msg: format!(
                        "iteration over `{name}` (a HashMap/HashSet binding) has \
                         nondeterministic order; collect into a sorted Vec or use \
                         a BTreeMap/BTreeSet"
                    ),
                });
            }
        }
    }
    out
}

/// Is token `k` (`Instant`) followed by `::now`?
fn followed_by_now(code: &str, toks: &[Tok], k: usize) -> bool {
    let b = code.as_bytes();
    let mut p = toks[k].end;
    while p < b.len() && b[p].is_ascii_whitespace() {
        p += 1;
    }
    if p + 1 >= b.len() || b[p] != b':' || b[p + 1] != b':' {
        return false;
    }
    toks.get(k + 1)
        .map(|n| &code[n.start..n.end] == "now")
        .unwrap_or(false)
}

/// Method name called directly on token `k` (`name.method`), if any.
fn method_after(code: &str, toks: &[Tok], k: usize) -> Option<String> {
    let b = code.as_bytes();
    let mut p = toks[k].end;
    while p < b.len() && b[p].is_ascii_whitespace() {
        p += 1;
    }
    if p >= b.len() || b[p] != b'.' {
        return None;
    }
    toks.get(k + 1).map(|n| code[n.start..n.end].to_string())
}

/// For `for … in <expr>`: does the loop source name a suspect binding?
/// Looks at the first idents after `in`, skipping `mut`/`self`.
fn loop_source_hit(code: &str, toks: &[Tok], k: usize, suspects: &[String]) -> Option<String> {
    let mut j = k + 1;
    for _ in 0..4 {
        let t = toks.get(j)?;
        let text = &code[t.start..t.end];
        if text == "mut" || text == "self" {
            j += 1;
            continue;
        }
        if suspects.iter().any(|s| s == text) {
            return Some(text.to_string());
        }
        return None;
    }
    None
}

/// Check the 4-lane accumulator structure of every allowlisted
/// reduction kernel in this file: each fn body must mention all four
/// lane identifiers, and every allowlisted name must still exist (a
/// rename without an allowlist update would otherwise silently disarm
/// the rule).
fn lane_structure(f: &FileAnalysis, kernels: &[&str], lines: &Lines) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut seen: Vec<&str> = Vec::new();
    for span in fn_spans(&f.code) {
        let Some(&name) = kernels.iter().find(|k| **k == span.name) else {
            continue;
        };
        let line = lines.line_of(span.open);
        if f.is_test_line(line) {
            continue;
        }
        seen.push(name);
        let body = &f.code[span.open..span.close];
        let body_idents = idents(body);
        let missing: Vec<&str> = LANES
            .iter()
            .copied()
            .filter(|lane| !body_idents.iter().any(|t| &body[t.start..t.end] == *lane))
            .collect();
        if !missing.is_empty() {
            out.push(Diagnostic {
                file: f.rel_path.clone(),
                line,
                rule: DETERMINISM,
                msg: format!(
                    "reduction kernel `{name}` lost its 4-lane accumulator \
                     structure (missing {}): hot-path reductions must keep the \
                     canonical `a0..a3` lane fold (see linalg/ops.rs module \
                     docs) so results stay bit-reproducible and vectorizable",
                    missing.join("/")
                ),
            });
        }
    }
    for k in kernels {
        if !seen.contains(k) {
            out.push(Diagnostic {
                file: f.rel_path.clone(),
                line: 1,
                rule: DETERMINISM,
                msg: format!(
                    "allowlisted reduction kernel `{k}` not found in this file; \
                     update the determinism rule's LANE_KERNELS allowlist if it \
                     moved or was renamed"
                ),
            });
        }
    }
    out
}

/// Names bound to a `HashMap`/`HashSet` type in this file: fields and
/// lets (`name: HashMap<…>`, `let name = HashMap::new()`), walking back
/// through path segments, `&`/`mut` sigils and generic wrappers
/// (`Mutex<HashMap<…>>`).
fn hash_binding_names(code: &str, toks: &[Tok]) -> Vec<String> {
    let b = code.as_bytes();
    let mut names: Vec<String> = Vec::new();
    for t in toks {
        let text = &code[t.start..t.end];
        if text != "HashMap" && text != "HashSet" {
            continue;
        }
        let mut pos = t.start;
        loop {
            skip_ws_back(b, &mut pos);
            if pos >= 2 && b[pos - 1] == b':' && b[pos - 2] == b':' {
                pos -= 2;
                skip_ws_back(b, &mut pos);
                if !eat_ident_back(b, &mut pos) {
                    break;
                }
            } else if pos >= 1 && b[pos - 1] == b'<' {
                pos -= 1;
                skip_ws_back(b, &mut pos);
                if !eat_ident_back(b, &mut pos) {
                    break;
                }
            } else if pos >= 1 && b[pos - 1] == b'&' {
                pos -= 1;
            } else if pos >= 1 && is_ident_byte(b[pos - 1]) {
                let s = ident_start_back(b, pos);
                if &code[s..pos] == "mut" {
                    pos = s;
                } else {
                    break;
                }
            } else {
                break;
            }
        }
        skip_ws_back(b, &mut pos);
        let name = if pos >= 1 && b[pos - 1] == b':' && (pos < 2 || b[pos - 2] != b':') {
            pos -= 1;
            skip_ws_back(b, &mut pos);
            ident_back(code, b, pos)
        } else if pos >= 1
            && b[pos - 1] == b'='
            && (pos < 2
                || !matches!(
                    b[pos - 2],
                    b'=' | b'!' | b'<' | b'>' | b'+' | b'-' | b'*' | b'/' | b'%' | b'&' | b'|' | b'^'
                ))
        {
            pos -= 1;
            skip_ws_back(b, &mut pos);
            ident_back(code, b, pos)
        } else {
            None
        };
        if let Some(n) = name {
            if n != "mut" && !names.contains(&n) {
                names.push(n);
            }
        }
    }
    names
}

fn skip_ws_back(b: &[u8], pos: &mut usize) {
    while *pos > 0 && b[*pos - 1].is_ascii_whitespace() {
        *pos -= 1;
    }
}

fn ident_start_back(b: &[u8], pos: usize) -> usize {
    let mut s = pos;
    while s > 0 && is_ident_byte(b[s - 1]) {
        s -= 1;
    }
    s
}

fn eat_ident_back(b: &[u8], pos: &mut usize) -> bool {
    let s = ident_start_back(b, *pos);
    let moved = s < *pos;
    *pos = s;
    moved
}

fn ident_back(code: &str, b: &[u8], pos: usize) -> Option<String> {
    let s = ident_start_back(b, pos);
    if s < pos && !b[s].is_ascii_digit() {
        Some(code[s..pos].to_string())
    } else {
        None
    }
}

// ---------------------------------------------------------- wire-totality

/// Every `Command`/`Reply` variant must have a tag constant
/// (`CMD_`/`REP_` + SCREAMING_SNAKE of the variant), an encode arm
/// (`push(TAG)`), a decode arm (`TAG … =>`), and coverage in
/// `rust/tests/wire_codec.rs` — including a use inside a test whose
/// name marks it as hostile-bytes (truncation/corruption/rejection).
/// Orphan tag constants and duplicate tag values are also errors.
pub fn wire_totality(wire: &FileAnalysis, codec: &FileAnalysis) -> Vec<Diagnostic> {
    let code = &wire.code;
    let mut out = Vec::new();
    let diag = |line: usize, msg: String| Diagnostic {
        file: wire.rel_path.clone(),
        line,
        rule: WIRE_TOTALITY,
        msg,
    };

    let cmd = enum_variants(code, "Command");
    let rep = enum_variants(code, "Reply");
    if cmd.is_empty() {
        out.push(diag(1, "`enum Command` not found (or has no variants)".into()));
    }
    if rep.is_empty() {
        out.push(diag(1, "`enum Reply` not found (or has no variants)".into()));
    }

    let consts = tag_consts(code);
    for i in 0..consts.len() {
        for j in i + 1..consts.len() {
            if let (Some(a), Some(b)) = (consts[i].value, consts[j].value) {
                if a == b {
                    out.push(diag(
                        consts[j].line,
                        format!(
                            "tag constants `{}` and `{}` share value {:#04x}",
                            consts[i].name, consts[j].name, a
                        ),
                    ));
                }
            }
        }
    }

    let toks = idents(code);
    let spans = fn_spans(&codec.code);
    let hostile: Vec<&FnSpan> = spans
        .iter()
        .filter(|s| HOSTILE_MARKERS.iter().any(|m| s.name.contains(m)))
        .collect();

    for (prefix, variants, enum_name) in
        [("CMD_", &cmd, "Command"), ("REP_", &rep, "Reply")]
    {
        for v in variants {
            let want = format!("{prefix}{}", screaming(&v.name));
            match consts.iter().find(|c| c.name == want) {
                None => out.push(diag(
                    v.line,
                    format!(
                        "variant `{enum_name}::{}` has no tag constant `{want}`",
                        v.name
                    ),
                )),
                Some(c) => {
                    if !has_push_use(code, &toks, &c.name) {
                        out.push(diag(
                            c.line,
                            format!("no encode arm pushes `{}` onto the wire", c.name),
                        ));
                    }
                    if !has_decode_arm(code, &toks, &c.name) {
                        out.push(diag(
                            c.line,
                            format!("no decode arm matches `{}`", c.name),
                        ));
                    }
                }
            }
            let positions = qualified_positions(&codec.code, enum_name, &v.name);
            if positions.is_empty() {
                out.push(diag(
                    v.line,
                    format!(
                        "`{enum_name}::{}` never appears in {} — add encode/decode \
                         and hostile-bytes coverage",
                        v.name, codec.rel_path
                    ),
                ));
            } else if !positions
                .iter()
                .any(|&p| hostile.iter().any(|s| p > s.open && p < s.close))
            {
                out.push(diag(
                    v.line,
                    format!(
                        "`{enum_name}::{}` has no hostile-bytes coverage in {}: no \
                         use inside a test whose name mentions {}",
                        v.name,
                        codec.rel_path,
                        HOSTILE_MARKERS.join("/")
                    ),
                ));
            }
        }
        for c in consts.iter().filter(|c| c.name.starts_with(prefix)) {
            let orphan = !variants
                .iter()
                .any(|v| format!("{prefix}{}", screaming(&v.name)) == c.name);
            if orphan {
                out.push(diag(
                    c.line,
                    format!(
                        "tag constant `{}` has no matching `{enum_name}` variant",
                        c.name
                    ),
                ));
            }
        }
    }
    out
}

/// `GradLoss` → `GRAD_LOSS`.
fn screaming(name: &str) -> String {
    let mut s = String::new();
    for (i, ch) in name.chars().enumerate() {
        if ch.is_ascii_uppercase() && i > 0 {
            s.push('_');
        }
        s.push(ch.to_ascii_uppercase());
    }
    s
}

struct Variant {
    name: String,
    line: usize,
}

/// Variant names of `enum <enum_name> { … }`: uppercase-initial idents
/// at brace depth 1 / paren depth 0 whose previous significant char is
/// `{` or `,` (so tuple/struct field types never count).
fn enum_variants(code: &str, enum_name: &str) -> Vec<Variant> {
    let b = code.as_bytes();
    let toks = idents(code);
    let lines = Lines::new(code);
    let mut body_start = None;
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != "enum" {
            continue;
        }
        if let Some(n) = toks.get(k + 1) {
            if &code[n.start..n.end] == enum_name {
                let mut p = n.end;
                while p < b.len() && b[p] != b'{' {
                    p += 1;
                }
                if p < b.len() {
                    body_start = Some(p + 1);
                }
                break;
            }
        }
    }
    let Some(start) = body_start else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut brace = 1i32;
    let mut paren = 0i32;
    let mut prev = b'{';
    let mut i = start;
    while i < b.len() && brace > 0 {
        let c = b[i];
        match c {
            b'{' => brace += 1,
            b'}' => brace -= 1,
            b'(' => paren += 1,
            b')' => paren -= 1,
            _ => {}
        }
        if c == b'_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            if brace == 1
                && paren == 0
                && (prev == b'{' || prev == b',')
                && b[s].is_ascii_uppercase()
            {
                out.push(Variant {
                    name: code[s..i].to_string(),
                    line: lines.line_of(s),
                });
            }
            prev = b[i - 1];
        } else {
            if !c.is_ascii_whitespace() {
                prev = c;
            }
            i += 1;
        }
    }
    out
}

struct TagConst {
    name: String,
    value: Option<u64>,
    line: usize,
}

/// `const CMD_*`/`const REP_*` declarations with their parsed values.
fn tag_consts(code: &str) -> Vec<TagConst> {
    let toks = idents(code);
    let lines = Lines::new(code);
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != "const" {
            continue;
        }
        let Some(n) = toks.get(k + 1) else { continue };
        let name = &code[n.start..n.end];
        if !name.starts_with("CMD_") && !name.starts_with("REP_") {
            continue;
        }
        let value = code[n.end..]
            .find('=')
            .map(|o| n.end + o)
            .and_then(|eq| {
                let semi = code[eq..].find(';').map(|o| eq + o)?;
                parse_int(code[eq + 1..semi].trim())
            });
        out.push(TagConst {
            name: name.to_string(),
            value,
            line: lines.line_of(t.start),
        });
    }
    out
}

fn parse_int(s: &str) -> Option<u64> {
    if let Some(h) = s.strip_prefix("0x") {
        u64::from_str_radix(h, 16).ok()
    } else {
        s.parse().ok()
    }
}

/// Is there a `….push(NAME)` call (an encode arm) anywhere?
fn has_push_use(code: &str, toks: &[Tok], name: &str) -> bool {
    let b = code.as_bytes();
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != name {
            continue;
        }
        if prev_sig(b, t.start) != Some(b'(') {
            continue;
        }
        if k > 0 && code[toks[k - 1].start..toks[k - 1].end].ends_with("push") {
            return true;
        }
    }
    false
}

/// Is there a match arm on NAME — `NAME =>`, `NAME if guard =>`, or
/// `NAME | OTHER =>`? (Scans forward from each non-definition use for
/// `=>` before the expression ends.)
fn has_decode_arm(code: &str, toks: &[Tok], name: &str) -> bool {
    let b = code.as_bytes();
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != name {
            continue;
        }
        if k > 0 && &code[toks[k - 1].start..toks[k - 1].end] == "const" {
            continue;
        }
        let lim = (t.end + 160).min(b.len());
        let mut p = t.end;
        while p + 1 < lim {
            match b[p] {
                b';' | b'{' => break,
                b'=' if b[p + 1] == b'>' => return true,
                _ => {}
            }
            p += 1;
        }
    }
    false
}

struct FnSpan {
    name: String,
    open: usize,
    close: usize,
}

/// Byte spans of every `fn name(…) { … }` body in masked code.
fn fn_spans(code: &str) -> Vec<FnSpan> {
    let b = code.as_bytes();
    let toks = idents(code);
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != "fn" {
            continue;
        }
        let Some(n) = toks.get(k + 1) else { continue };
        let mut p = n.end;
        let mut paren = 0i32;
        let mut open = None;
        while p < b.len() {
            match b[p] {
                b'(' => paren += 1,
                b')' => paren -= 1,
                b'{' if paren == 0 => {
                    open = Some(p);
                    break;
                }
                b';' if paren == 0 => break,
                _ => {}
            }
            p += 1;
        }
        let Some(open) = open else { continue };
        let mut depth = 0i32;
        let mut q = open;
        let mut close = b.len();
        while q < b.len() {
            match b[q] {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        close = q;
                        break;
                    }
                }
                _ => {}
            }
            q += 1;
        }
        out.push(FnSpan {
            name: code[n.start..n.end].to_string(),
            open,
            close,
        });
    }
    out
}

/// Byte positions of every `EnumName::Variant` mention.
fn qualified_positions(code: &str, enum_name: &str, variant: &str) -> Vec<usize> {
    let b = code.as_bytes();
    let toks = idents(code);
    let mut out = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != enum_name {
            continue;
        }
        let mut p = t.end;
        while p < b.len() && b[p].is_ascii_whitespace() {
            p += 1;
        }
        if p + 1 >= b.len() || b[p] != b':' || b[p + 1] != b':' {
            continue;
        }
        if let Some(n) = toks.get(k + 1) {
            if &code[n.start..n.end] == variant {
                out.push(t.start);
            }
        }
    }
    out
}

// ------------------------------------------------------------- csv-schema

/// The trace CSV schema must agree everywhere it is spelled out:
/// `TraceRow` field order ≡ `CSV_HEADER` columns ≡ the row format
/// string's placeholder count, and every `name (col N)` / `name (N)`
/// annotation, awk `$N` and `cut -f` spec in emit.rs/ci.yml must point
/// at a real column.
pub fn csv_schema(
    trace: &FileAnalysis,
    emit: &FileAnalysis,
    ci_raw: &str,
    ci_rel: &str,
) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    let fields = struct_fields(&trace.code, "TraceRow");
    if fields.is_empty() {
        out.push(Diagnostic {
            file: trace.rel_path.clone(),
            line: 1,
            rule: CSV_SCHEMA,
            msg: "`struct TraceRow` not found (or has no fields)".into(),
        });
    }
    let Some((cols, hline)) = csv_header(&emit.raw) else {
        out.push(Diagnostic {
            file: emit.rel_path.clone(),
            line: 1,
            rule: CSV_SCHEMA,
            msg: "`const CSV_HEADER` string not found".into(),
        });
        return out;
    };

    let field_names: Vec<&str> = fields.iter().map(|(n, _)| n.as_str()).collect();
    let col_names: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    if !fields.is_empty() && field_names != col_names {
        out.push(Diagnostic {
            file: emit.rel_path.clone(),
            line: hline,
            rule: CSV_SCHEMA,
            msg: format!(
                "CSV_HEADER columns [{}] disagree with TraceRow fields [{}] \
                 (names and order must match exactly)",
                col_names.join(","),
                field_names.join(",")
            ),
        });
    }

    let ncols = cols.len();
    match row_format_placeholders(&emit.raw) {
        None => out.push(Diagnostic {
            file: emit.rel_path.clone(),
            line: 1,
            rule: CSV_SCHEMA,
            msg: "trace row format string (a literal starting `{},`) not found".into(),
        }),
        Some((count, line)) => {
            if count != ncols {
                out.push(Diagnostic {
                    file: emit.rel_path.clone(),
                    line,
                    rule: CSV_SCHEMA,
                    msg: format!(
                        "trace row format writes {count} fields but CSV_HEADER has \
                         {ncols} columns"
                    ),
                });
            }
        }
    }

    out.extend(annotation_drift(&emit.raw, &emit.rel_path, &cols));
    out.extend(annotation_drift(ci_raw, ci_rel, &cols));
    out.extend(dollar_bounds(ci_raw, ci_rel, ncols));
    out.extend(cut_bounds(ci_raw, ci_rel, ncols));
    out
}

/// Field names of `struct <name> { pub a: …, pub b: …, … }` in order.
fn struct_fields(code: &str, name: &str) -> Vec<(String, usize)> {
    let b = code.as_bytes();
    let toks = idents(code);
    let lines = Lines::new(code);
    let mut body_start = None;
    for (k, t) in toks.iter().enumerate() {
        if &code[t.start..t.end] != "struct" {
            continue;
        }
        if let Some(n) = toks.get(k + 1) {
            if &code[n.start..n.end] == name {
                let mut p = n.end;
                while p < b.len() && b[p] != b'{' && b[p] != b';' {
                    p += 1;
                }
                if p < b.len() && b[p] == b'{' {
                    body_start = Some(p + 1);
                }
                break;
            }
        }
    }
    let Some(start) = body_start else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut brace = 1i32;
    let mut paren = 0i32;
    let mut prev = b'{';
    let mut i = start;
    while i < b.len() && brace > 0 {
        let c = b[i];
        match c {
            b'{' => brace += 1,
            b'}' => brace -= 1,
            b'(' => paren += 1,
            b')' => paren -= 1,
            _ => {}
        }
        if c == b'_' || c.is_ascii_alphabetic() {
            let s = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            let text = &code[s..i];
            if brace == 1 && paren == 0 && (prev == b'{' || prev == b',') {
                if text == "pub" {
                    // keep `prev` so the field name after `pub` still
                    // sees `{`/`,` as its opener
                    continue;
                }
                if next_sig(b, i) == Some(b':') {
                    out.push((text.to_string(), lines.line_of(s)));
                }
            }
            prev = b[i - 1];
        } else {
            if !c.is_ascii_whitespace() {
                prev = c;
            }
            i += 1;
        }
    }
    out
}

/// The `const CSV_HEADER` string: column names and the line it sits on.
fn csv_header(raw: &str) -> Option<(Vec<String>, usize)> {
    let at = raw.find("const CSV_HEADER")?;
    let q1 = at + raw[at..].find('"')?;
    let q2 = q1 + 1 + raw[q1 + 1..].find('"')?;
    let line = raw[..q1].matches('\n').count() + 1;
    Some((
        raw[q1 + 1..q2]
            .split(',')
            .map(|s| s.trim().to_string())
            .collect(),
        line,
    ))
}

/// Placeholder count and line of the trace row format string (the
/// literal starting `"{},`).
fn row_format_placeholders(raw: &str) -> Option<(usize, usize)> {
    let at = raw.find("\"{},")?;
    let b = raw.as_bytes();
    let mut j = at + 1;
    let mut count = 0usize;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 1,
            b'"' => break,
            b'{' => {
                if j + 1 < b.len() && b[j + 1] == b'{' {
                    j += 1;
                } else {
                    count += 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    Some((count, raw[..at].matches('\n').count() + 1))
}

/// `name (col N)` / `name (N)` annotations that name a header column
/// but point at the wrong 1-based index.
fn annotation_drift(raw: &str, rel: &str, cols: &[String]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (li, line) in raw.lines().enumerate() {
        let lb = line.as_bytes();
        for (ci, col) in cols.iter().enumerate() {
            let want = ci + 1;
            let mut from = 0usize;
            while let Some(off) = line.get(from..).and_then(|s| s.find(col.as_str())) {
                let s = from + off;
                let e = s + col.len();
                from = s + 1;
                let before_ok = s == 0 || !is_ident_byte(lb[s - 1]);
                let after_ok = e >= lb.len() || !is_ident_byte(lb[e]);
                if !before_ok || !after_ok {
                    continue;
                }
                let mut p = e;
                while p < lb.len() && lb[p] == b' ' {
                    p += 1;
                }
                if p >= lb.len() || lb[p] != b'(' {
                    continue;
                }
                let rest = &line[p + 1..];
                let rest = rest.strip_prefix("col ").unwrap_or(rest);
                let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                if digits.is_empty() || !rest[digits.len()..].starts_with(')') {
                    continue;
                }
                if let Ok(n) = digits.parse::<usize>() {
                    if n != want {
                        out.push(Diagnostic {
                            file: rel.to_string(),
                            line: li + 1,
                            rule: CSV_SCHEMA,
                            msg: format!(
                                "annotation says `{col}` is column {n} but CSV_HEADER \
                                 puts it at column {want}"
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

/// awk-style `$N` references beyond the column count.
fn dollar_bounds(raw: &str, rel: &str, ncols: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (li, line) in raw.lines().enumerate() {
        let lb = line.as_bytes();
        let mut i = 0usize;
        while i < lb.len() {
            if lb[i] != b'$' {
                i += 1;
                continue;
            }
            let mut j = i + 1;
            while j < lb.len() && lb[j].is_ascii_digit() {
                j += 1;
            }
            if j > i + 1 {
                if let Ok(n) = line[i + 1..j].parse::<usize>() {
                    if n > ncols {
                        out.push(Diagnostic {
                            file: rel.to_string(),
                            line: li + 1,
                            rule: CSV_SCHEMA,
                            msg: format!(
                                "`${n}` is out of range: the trace CSV has only \
                                 {ncols} columns"
                            ),
                        });
                    }
                }
            }
            i = j.max(i + 1);
        }
    }
    out
}

/// `cut … -f<spec>` field specs referencing columns beyond the count.
fn cut_bounds(raw: &str, rel: &str, ncols: usize) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (li, line) in raw.lines().enumerate() {
        if !line.contains("cut") {
            continue;
        }
        let mut from = 0usize;
        while let Some(off) = line.get(from..).and_then(|s| s.find("-f")) {
            let start = from + off + 2;
            from = start;
            let spec: String = line[start..]
                .chars()
                .take_while(|c| c.is_ascii_digit() || *c == ',' || *c == '-')
                .collect();
            for part in spec.split(|c| c == ',' || c == '-') {
                if let Ok(n) = part.parse::<usize>() {
                    if n > ncols {
                        out.push(Diagnostic {
                            file: rel.to_string(),
                            line: li + 1,
                            rule: CSV_SCHEMA,
                            msg: format!(
                                "`cut -f` references column {n} but the trace CSV \
                                 has only {ncols} columns"
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fa(path: &str, src: &str) -> FileAnalysis {
        FileAnalysis::new(path, src)
    }

    #[test]
    fn panic_freedom_flags_only_scoped_non_test_code() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap()\n}\nfn g() {\n    panic!(\"boom\");\n}\n#[cfg(test)]\nmod tests {\n    fn t() { None::<u8>.unwrap(); }\n}\n";
        let d = panic_freedom(&fa("rust/src/comm/x.rs", src));
        assert_eq!(d.len(), 2, "{d:?}");
        assert_eq!(d[0].line, 2);
        assert_eq!(d[1].line, 5);
        assert!(panic_freedom(&fa("rust/src/linalg/x.rs", src)).is_empty());
    }

    #[test]
    fn panic_freedom_ignores_lookalikes() {
        let src = "fn f(x: Option<u8>) -> u8 {\n    x.unwrap_or(0)\n}\nfn g(m: &M) -> u8 {\n    m.lock().unwrap_or_else(|e| e.into_inner())\n}\n// a comment saying .unwrap() is bad\nfn h() -> &'static str {\n    \"do not panic!(now)\"\n}\n";
        assert!(panic_freedom(&fa("rust/src/comm/x.rs", src)).is_empty());
    }

    #[test]
    fn densify_allows_linalg_and_tests_only() {
        let src = "fn f(m: &CsrMatrix) -> DenseMatrix {\n    m.to_dense()\n}\n#[cfg(test)]\nmod tests {\n    fn t(m: &CsrMatrix) { m.to_dense(); }\n}\n";
        let d = densify(&fa("rust/src/worker/x.rs", src));
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].line, 2);
        assert!(densify(&fa("rust/src/linalg/sparse.rs", src)).is_empty());
    }

    #[test]
    fn determinism_flags_clocks_outside_allowlist() {
        let src = "fn f() -> Instant {\n    Instant::now()\n}\nfn g() -> SystemTime {\n    SystemTime::now()\n}\n";
        let d = determinism(&fa("rust/src/worker/x.rs", src));
        assert_eq!(d.len(), 2, "{d:?}");
        assert!(determinism(&fa("rust/src/coordinator/dane.rs", src)).is_empty());
    }

    #[test]
    fn determinism_flags_hash_iteration_not_keyed_access() {
        let src = "use std::collections::HashMap;\nstruct S {\n    flags: HashMap<String, String>,\n}\nfn f(s: &S) -> Vec<String> {\n    s.flags.keys().cloned().collect()\n}\nfn g(s: &S) -> Option<&String> {\n    s.flags.get(\"x\")\n}\nfn h(v: &[u8]) {\n    for x in v.iter() {\n        let _ = x;\n    }\n}\n";
        let d = determinism(&fa("rust/src/worker/x.rs", src));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 6);
        assert!(d[0].msg.contains("flags"));
    }

    const LANED_DIST2: &str = "pub fn dist2(x: &[f64], y: &[f64]) -> f64 {\n    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);\n    a0 += 1.0; a1 += 1.0; a2 += 1.0; a3 += 1.0;\n    (a0 + a2) + (a1 + a3)\n}\n";

    #[test]
    fn determinism_flags_scalar_reductions_in_allowlisted_kernels() {
        // a `dot` that lost its lanes next to an intact `dist2`: exactly
        // one diagnostic, naming the kernel and the missing lanes
        let src = format!(
            "pub fn dot(x: &[f64], y: &[f64]) -> f64 {{\n    let mut acc = 0.0;\n    for i in 0..x.len() {{\n        acc += x[i] * y[i];\n    }}\n    acc\n}}\n{LANED_DIST2}"
        );
        let d = determinism(&fa("rust/src/linalg/ops.rs", &src));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 1);
        assert!(d[0].msg.contains("`dot`") && d[0].msg.contains("a0"), "{d:?}");
        // the same scalar loop outside the allowlisted files is not
        // this rule's business
        assert!(determinism(&fa("rust/src/worker/x.rs", &src)).is_empty());
        // both kernels laned -> clean
        let good = format!(
            "pub fn dot(x: &[f64], y: &[f64]) -> f64 {{\n    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0, 0.0, 0.0);\n    a0 += 1.0; a1 += 1.0; a2 += 1.0; a3 += 1.0;\n    (a0 + a2) + (a1 + a3)\n}}\n{LANED_DIST2}"
        );
        assert!(determinism(&fa("rust/src/linalg/ops.rs", &good)).is_empty());
    }

    #[test]
    fn determinism_reports_vanished_allowlisted_kernels() {
        // `dot` renamed away entirely: the allowlist must not silently
        // disarm
        let d = determinism(&fa("rust/src/linalg/ops.rs", LANED_DIST2));
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(
            d[0].msg.contains("`dot` not found"),
            "{d:?}"
        );
    }

    #[test]
    fn determinism_flags_for_loops_over_hash_bindings() {
        let src = "fn f() -> u64 {\n    let mut acc = 0;\n    let m: std::collections::HashMap<u32, u64> = Default::default();\n    for v in &m {\n        acc += v.1;\n    }\n    acc\n}\n";
        let d = determinism(&fa("rust/src/coordinator/x.rs", src));
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 4);
    }

    const WIRE_OK: &str = "pub const CMD_INIT: u8 = 0x01;\npub const CMD_GRAD_LOSS: u8 = 0x02;\npub const REP_VEC: u8 = 0x81;\npub enum Command {\n    Init(Vec<u8>),\n    GradLoss { w: Vec<f64>, out: Vec<f64> },\n}\npub enum Reply {\n    Vec(Vec<f64>),\n}\nfn put(buf: &mut Vec<u8>, c: &Command) {\n    match c {\n        Command::Init(_) => buf.push(CMD_INIT),\n        Command::GradLoss { .. } => buf.push(CMD_GRAD_LOSS),\n    }\n}\nfn put_reply(buf: &mut Vec<u8>, r: &Reply) {\n    match r {\n        Reply::Vec(_) => buf.push(REP_VEC),\n    }\n}\nfn take(tag: u8) -> Result<(), ()> {\n    match tag {\n        CMD_INIT => Ok(()),\n        CMD_GRAD_LOSS if true => Ok(()),\n        REP_VEC => Ok(()),\n        _ => Err(()),\n    }\n}\n";

    const CODEC_OK: &str = "#[test]\nfn roundtrip() {\n    let c = Command::Init(vec![]);\n    let g = Command::GradLoss { w: vec![], out: vec![] };\n    let r = Reply::Vec(vec![]);\n}\n#[test]\nfn every_truncation_is_an_error() {\n    let frames = [Command::Init(vec![]), Command::GradLoss { w: vec![], out: vec![] }];\n    let replies = [Reply::Vec(vec![])];\n}\n";

    #[test]
    fn wire_totality_passes_a_complete_protocol() {
        let wire = fa("rust/src/comm/wire.rs", WIRE_OK);
        let codec = fa("rust/tests/wire_codec.rs", CODEC_OK);
        let d = wire_totality(&wire, &codec);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn wire_totality_catches_missing_tag_arms_and_coverage() {
        // add a variant with no const, an orphan const, a duplicate value
        let src = WIRE_OK.replace(
            "    GradLoss { w: Vec<f64>, out: Vec<f64> },\n",
            "    GradLoss { w: Vec<f64>, out: Vec<f64> },\n    RowSq,\n",
        ) + "pub const CMD_PEERS: u8 = 0x01;\n";
        let wire = fa("rust/src/comm/wire.rs", &src);
        let codec = fa("rust/tests/wire_codec.rs", CODEC_OK);
        let d = wire_totality(&wire, &codec);
        let msgs: Vec<&str> = d.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("`Command::RowSq` has no tag constant")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("CMD_PEERS") && m.contains("no matching")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("share value")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`Command::RowSq` never appears")), "{msgs:?}");
    }

    #[test]
    fn wire_totality_requires_hostile_coverage() {
        // covered in a roundtrip test only -> hostile-coverage diagnostic
        let codec_src = "#[test]\nfn roundtrip() {\n    let c = Command::Init(vec![]);\n    let g = Command::GradLoss { w: vec![], out: vec![] };\n    let r = Reply::Vec(vec![]);\n}\n";
        let wire = fa("rust/src/comm/wire.rs", WIRE_OK);
        let codec = fa("rust/tests/wire_codec.rs", codec_src);
        let d = wire_totality(&wire, &codec);
        assert_eq!(d.len(), 3, "{d:?}");
        assert!(d.iter().all(|x| x.msg.contains("no hostile-bytes coverage")));
    }

    const TRACE_OK: &str = "pub struct TraceRow {\n    pub round: usize,\n    pub objective: f64,\n    pub comm_bytes: u64,\n}\n";
    const EMIT_OK: &str = "pub const CSV_HEADER: &str = \"round,objective,comm_bytes\";\n// objective (col 2) is the regularized loss\nfn row() {\n    let _ = format!(\"{},{:.17e},{}\", 1, 2.0, 3);\n}\n";

    #[test]
    fn csv_schema_passes_when_everything_agrees() {
        let trace = fa("rust/src/metrics/trace.rs", TRACE_OK);
        let emit = fa("rust/src/metrics/emit.rs", EMIT_OK);
        let ci = "run: awk -F, '{print $3}' trace.csv | cut -d, -f1-3 # comm_bytes (3)\n";
        let d = csv_schema(&trace, &emit, ci, ".github/workflows/ci.yml");
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn csv_schema_catches_drift_everywhere() {
        let trace = fa(
            "rust/src/metrics/trace.rs",
            "pub struct TraceRow {\n    pub round: usize,\n    pub comm_bytes: u64,\n    pub objective: f64,\n}\n",
        );
        let emit = fa(
            "rust/src/metrics/emit.rs",
            "pub const CSV_HEADER: &str = \"round,objective,comm_bytes\";\n// objective (col 3) stale note\nfn row() {\n    let _ = format!(\"{},{:.17e}\", 1, 2.0);\n}\n",
        );
        let ci = "run: awk -F, '{print $9}' trace.csv | cut -d, -f1-8\n";
        let d = csv_schema(&trace, &emit, ci, ".github/workflows/ci.yml");
        let msgs: Vec<&str> = d.iter().map(|x| x.msg.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("disagree with TraceRow")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("writes 2 fields")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("column 3") && m.contains("column 2")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("`$9` is out of range")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("cut -f` references column 8")), "{msgs:?}");
    }

    #[test]
    fn screaming_snake_mapping() {
        assert_eq!(screaming("Init"), "INIT");
        assert_eq!(screaming("GradLoss"), "GRAD_LOSS");
        assert_eq!(screaming("RowSq"), "ROW_SQ");
        assert_eq!(screaming("For"), "FOR");
        assert_eq!(screaming("VecScalar"), "VEC_SCALAR");
    }
}
