//! `dane-lint`: the in-tree static-analysis pass behind `cargo run --bin
//! dane-lint` and the CI `lint` job.
//!
//! Seven PRs of reviewer discipline keep two load-bearing invariants
//! alive — bit-exact cross-engine/topology parity, and "no panic
//! reachable from a worker failure or a hostile byte stream". This
//! module makes them machine-checkable. Five rules, each guarding a
//! contract that already exists in the tree:
//!
//! | rule | contract |
//! |---|---|
//! | `panic-freedom` | no `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!` in non-test code under `coordinator/`, `comm/`, `worker/` |
//! | `densify` | `to_dense(` only inside `linalg/` internals and test scopes — dense materialization must never creep onto the big-data path |
//! | `wire-totality` | every `Command`/`Reply` variant has a tag constant, an encode arm, a decode arm, and hostile-bytes coverage in `tests/wire_codec.rs` |
//! | `csv-schema` | `TraceRow` fields ≡ `emit.rs` CSV header ≡ the column indices hardcoded in `ci.yml` awk/cut pipelines |
//! | `determinism` | no `HashMap`/`HashSet` iteration feeding folds or output, no `Instant::now`/`SystemTime::now` outside the metrics timing allowlist |
//!
//! Escape hatch: `// lint:allow(<rule>): <reason>` on the violating
//! line or the line above suppresses exactly one line's findings for
//! one rule. The reason is mandatory, unknown rule names are errors,
//! and an allow that suppresses nothing is itself an error
//! (`lint-allow`), so annotations cannot go stale silently.
//!
//! All scanning happens on masked source ([`lexer`]): comments and
//! string contents never trip a rule, and `#[cfg(test)]` scopes
//! ([`scope`]) are exempt where a rule says so. The rules themselves
//! live in [`rules`]; everything is a plain function over in-memory
//! strings, so `tests/lint_self.rs` can feed fixture snippets through
//! the exact code path CI runs.

pub mod lexer;
pub mod rules;
pub mod scope;

use std::path::{Path, PathBuf};

/// Every rule id `lint:allow(...)` may name.
pub const RULE_IDS: &[&str] = &[
    rules::PANIC_FREEDOM,
    rules::DENSIFY,
    rules::WIRE_TOTALITY,
    rules::CSV_SCHEMA,
    rules::DETERMINISM,
];

/// One finding: `file:line: rule: message`.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Repo-relative path with `/` separators.
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (one of [`RULE_IDS`], or `lint-allow` for marker misuse).
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.msg)
    }
}

/// A parsed `// lint:allow(<rule>): <reason>` marker.
#[derive(Debug, Clone)]
pub struct Allow {
    /// Line the comment sits on.
    pub line: usize,
    /// The rule it suppresses.
    pub rule: String,
    /// The (mandatory) justification.
    pub reason: String,
    /// The code line it applies to: its own line when that line has
    /// code, else the next line that does.
    pub target_line: usize,
}

/// One file, lexed and scope-tracked, ready for the rules.
#[derive(Debug)]
pub struct FileAnalysis {
    /// Repo-relative path with `/` separators (rules match on it).
    pub rel_path: String,
    /// Original text (cross-reference rules read literals from it).
    pub raw: String,
    /// Masked code ([`lexer::mask`]).
    pub code: String,
    /// Per-line `#[cfg(test)]` flags.
    pub test_lines: Vec<bool>,
    /// Parsed allow markers.
    pub allows: Vec<Allow>,
    /// Malformed markers found while parsing (missing reason, unknown
    /// rule) — always reported.
    pub marker_errors: Vec<Diagnostic>,
}

impl FileAnalysis {
    pub fn new(rel_path: &str, source: &str) -> FileAnalysis {
        let masked = lexer::mask(source);
        let test_lines = scope::test_lines(&masked.code);
        let mut allows = Vec::new();
        let mut marker_errors = Vec::new();
        let line_has_code = line_code_flags(&masked.code);
        for c in &masked.comments {
            parse_allow(
                rel_path,
                c,
                &line_has_code,
                &mut allows,
                &mut marker_errors,
            );
        }
        FileAnalysis {
            rel_path: rel_path.to_string(),
            raw: source.to_string(),
            code: masked.code,
            test_lines,
            allows,
            marker_errors,
        }
    }

    /// Is 1-based `line` inside test scope?
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_lines.get(line.saturating_sub(1)).copied().unwrap_or(false)
    }
}

/// Per-line "has any non-whitespace masked code" flags.
fn line_code_flags(code: &str) -> Vec<bool> {
    code.lines().map(|l| !l.trim().is_empty()).collect()
}

fn parse_allow(
    rel_path: &str,
    c: &lexer::Comment,
    line_has_code: &[bool],
    allows: &mut Vec<Allow>,
    errors: &mut Vec<Diagnostic>,
) {
    // A marker must BE the comment, not merely appear in it: strip the
    // comment leader (`//`, `//!`, `///`, `/*`, `/**`) plus whitespace
    // and require `lint:allow` as a prefix. Prose that mentions the
    // syntax mid-sentence (this module's own docs, say) is not a marker.
    let body = c.text.trim_start_matches(['/', '*', '!']).trim_start();
    if !body.starts_with("lint:allow") {
        return;
    }
    let rest = &body["lint:allow".len()..];
    let bad = |msg: String| Diagnostic {
        file: rel_path.to_string(),
        line: c.line,
        rule: rules::LINT_ALLOW,
        msg,
    };
    let Some(rest) = rest.strip_prefix('(') else {
        errors.push(bad("malformed marker: expected `lint:allow(<rule>): <reason>`".into()));
        return;
    };
    let Some(close) = rest.find(')') else {
        errors.push(bad("malformed marker: unclosed `(`".into()));
        return;
    };
    let rule = rest[..close].trim().to_string();
    if !RULE_IDS.contains(&rule.as_str()) {
        errors.push(bad(format!(
            "unknown rule {rule:?}; valid rules: {}",
            RULE_IDS.join(", ")
        )));
        return;
    }
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
    if reason.is_empty() {
        errors.push(bad(format!(
            "lint:allow({rule}) needs a reason: `lint:allow({rule}): <why this site is safe>`"
        )));
        return;
    }
    // target: this line if it carries code, else the next line that does
    let mut target = c.line;
    let has_code =
        |ln: usize| line_has_code.get(ln - 1).copied().unwrap_or(false);
    if !has_code(target) {
        let mut ln = c.line + 1;
        while ln <= line_has_code.len() && !has_code(ln) {
            ln += 1;
        }
        target = ln;
    }
    allows.push(Allow {
        line: c.line,
        rule,
        reason: reason.to_string(),
        target_line: target,
    });
}

/// Filter `diags` through the allow markers of `files`; append
/// marker-misuse findings (malformed markers, markers that suppressed
/// nothing).
pub fn apply_allows(diags: Vec<Diagnostic>, files: &[&FileAnalysis]) -> Vec<Diagnostic> {
    let mut used: Vec<Vec<bool>> =
        files.iter().map(|f| vec![false; f.allows.len()]).collect();
    let mut out: Vec<Diagnostic> = Vec::new();
    for d in diags {
        let mut suppressed = false;
        for (fi, f) in files.iter().enumerate() {
            if f.rel_path != d.file {
                continue;
            }
            for (ai, a) in f.allows.iter().enumerate() {
                if a.rule == d.rule && a.target_line == d.line {
                    used[fi][ai] = true;
                    suppressed = true;
                }
            }
        }
        if !suppressed {
            out.push(d);
        }
    }
    for (fi, f) in files.iter().enumerate() {
        for e in &f.marker_errors {
            out.push(e.clone());
        }
        for (ai, a) in f.allows.iter().enumerate() {
            if !used[fi][ai] {
                out.push(Diagnostic {
                    file: f.rel_path.clone(),
                    line: a.line,
                    rule: rules::LINT_ALLOW,
                    msg: format!(
                        "stale lint:allow({}): nothing on line {} trips the rule — \
                         remove the marker or the fix regressed",
                        a.rule, a.target_line
                    ),
                });
            }
        }
    }
    dedup_sort(&mut out);
    out
}

fn dedup_sort(diags: &mut Vec<Diagnostic>) {
    diags.sort_by(|a, b| {
        (&a.file, a.line, a.rule, &a.msg).cmp(&(&b.file, b.line, b.rule, &b.msg))
    });
    diags.dedup();
}

/// Lint the whole repository rooted at `root` (the directory holding
/// `rust/src`). This is exactly what the `dane-lint` binary and the
/// `lint_self` integration test run.
pub fn lint_repo(root: &Path) -> std::io::Result<Vec<Diagnostic>> {
    let src_root = root.join("rust").join("src");
    let mut files = Vec::new();
    let mut paths = Vec::new();
    walk_rs(&src_root, &mut paths)?;
    // deterministic order whatever the OS returns
    paths.sort();
    for p in &paths {
        let source = std::fs::read_to_string(p)?;
        let rel = rel_unix(root, p);
        files.push(FileAnalysis::new(&rel, &source));
    }

    let mut diags = Vec::new();
    for f in &files {
        diags.extend(rules::panic_freedom(f));
        diags.extend(rules::densify(f));
        diags.extend(rules::determinism(f));
    }

    // cross-reference rules need their anchor files
    let codec_path = root.join("rust").join("tests").join("wire_codec.rs");
    let codec = match std::fs::read_to_string(&codec_path) {
        Ok(s) => Some(FileAnalysis::new(&rel_unix(root, &codec_path), &s)),
        Err(_) => {
            diags.push(Diagnostic {
                file: "rust/tests/wire_codec.rs".into(),
                line: 1,
                rule: rules::WIRE_TOTALITY,
                msg: "hostile-bytes suite missing: cannot cross-check wire variants".into(),
            });
            None
        }
    };
    if let (Some(wire), Some(codec)) = (
        files.iter().find(|f| f.rel_path == "rust/src/comm/wire.rs"),
        codec.as_ref(),
    ) {
        diags.extend(rules::wire_totality(wire, codec));
    }

    let ci_path = root.join(".github").join("workflows").join("ci.yml");
    let ci_raw = std::fs::read_to_string(&ci_path).unwrap_or_default();
    if let (Some(trace), Some(emit)) = (
        files.iter().find(|f| f.rel_path == "rust/src/metrics/trace.rs"),
        files.iter().find(|f| f.rel_path == "rust/src/metrics/emit.rs"),
    ) {
        diags.extend(rules::csv_schema(
            trace,
            emit,
            &ci_raw,
            ".github/workflows/ci.yml",
        ));
    }

    let mut refs: Vec<&FileAnalysis> = files.iter().collect();
    if let Some(c) = codec.as_ref() {
        refs.push(c);
    }
    Ok(apply_allows(diags, &refs))
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

fn rel_unix(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allow_marker_parses_and_targets_next_code_line() {
        let src = "fn f() {\n    // lint:allow(panic-freedom): spawn failure is bring-up only\n    // second comment line\n    x.unwrap();\n}\n";
        let fa = FileAnalysis::new("rust/src/comm/x.rs", src);
        assert_eq!(fa.allows.len(), 1);
        assert_eq!(fa.allows[0].rule, "panic-freedom");
        assert_eq!(fa.allows[0].target_line, 4);
        assert!(fa.marker_errors.is_empty());
    }

    #[test]
    fn allow_on_code_line_targets_itself() {
        let src = "fn f() {\n    x.unwrap(); // lint:allow(panic-freedom): reason here\n}\n";
        let fa = FileAnalysis::new("rust/src/comm/x.rs", src);
        assert_eq!(fa.allows[0].target_line, 2);
    }

    #[test]
    fn missing_reason_and_unknown_rule_are_marker_errors() {
        let src = "// lint:allow(panic-freedom)\n// lint:allow(bogus-rule): why\nfn f() {}\n";
        let fa = FileAnalysis::new("rust/src/comm/x.rs", src);
        assert!(fa.allows.is_empty());
        assert_eq!(fa.marker_errors.len(), 2);
        assert!(fa.marker_errors[0].msg.contains("needs a reason"));
        assert!(fa.marker_errors[1].msg.contains("unknown rule"));
    }

    #[test]
    fn stale_allow_is_reported() {
        let src = "fn f() {\n    // lint:allow(panic-freedom): nothing here anymore\n    let x = 1;\n}\n";
        let fa = FileAnalysis::new("rust/src/comm/x.rs", src);
        let out = apply_allows(Vec::new(), &[&fa]);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].rule, rules::LINT_ALLOW);
        assert!(out[0].msg.contains("stale"));
    }

    #[test]
    fn allow_suppresses_matching_rule_and_line_only() {
        let src = "fn f() {\n    // lint:allow(panic-freedom): justified\n    a.unwrap();\n    b.unwrap();\n}\n";
        let fa = FileAnalysis::new("rust/src/comm/x.rs", src);
        let diags = rules::panic_freedom(&fa);
        assert_eq!(diags.len(), 2);
        let out = apply_allows(diags, &[&fa]);
        assert_eq!(out.len(), 1, "line 4 must still be reported: {out:?}");
        assert_eq!(out[0].line, 4);
    }
}
