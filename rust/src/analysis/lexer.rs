//! Comment- and string-aware source masking.
//!
//! Every `dane-lint` rule works on **masked** source: the same byte
//! length and line structure as the input, but with comment bodies and
//! string/char-literal contents blanked to spaces. That is what makes
//! the rules honest — `.expect()` inside a doc comment (there is one in
//! `coordinator/mod.rs`) or `panic!` inside an error-message string is
//! never a violation, and a `lint:allow` marker hidden inside a string
//! literal is never an escape hatch.
//!
//! The lexer understands exactly the token classes that can embed
//! look-alike code in Rust source:
//!
//! * `//` line comments (incl. `///` and `//!` doc comments);
//! * `/* … */` block comments, **nested**, as in real Rust;
//! * `"…"` string literals with `\` escapes, plus `b"…"` byte strings;
//! * `r"…"`, `r#"…"#`, … raw strings with any number of `#` guards
//!   (and their `br` byte variants);
//! * `'x'` char literals (with escapes) vs. `'a` lifetimes — a quote
//!   followed by an escape or by exactly one char and a closing quote
//!   is a literal, anything else is a lifetime and left alone.
//!
//! Comments are additionally collected verbatim (with their line
//! numbers) so the allow-marker parser and the column-annotation checks
//! can read them without re-lexing.

/// One comment as it appeared in the source, `//`/`/*` markers included.
#[derive(Debug, Clone, PartialEq)]
pub struct Comment {
    /// 1-based line the comment starts on.
    pub line: usize,
    /// Verbatim text, markers included; block comments keep embedded
    /// newlines.
    pub text: String,
}

/// The masked view of one source file.
#[derive(Debug, Clone)]
pub struct Masked {
    /// Source with comments and literal bodies blanked to spaces.
    /// Newlines are preserved, so byte offsets and line numbers agree
    /// with the original text.
    pub code: String,
    /// Every comment, in source order.
    pub comments: Vec<Comment>,
}

/// Mask `src`: blank comments and string/char-literal contents, keep
/// everything else (including line structure) byte-for-byte.
pub fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let mut code = Vec::with_capacity(b.len());
    let mut comments = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            code.push(b'\n');
            line += 1;
            i += 1;
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                code.push(b' ');
                i += 1;
            }
            comments.push(Comment { line, text: src[start..i].to_string() });
        } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 0usize;
            while i < b.len() {
                if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    code.extend_from_slice(b"  ");
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    code.extend_from_slice(b"  ");
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    if b[i] == b'\n' {
                        code.push(b'\n');
                        line += 1;
                    } else {
                        code.push(b' ');
                    }
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: src[start..i].to_string() });
        } else if let Some(len) = raw_string_len(b, i) {
            blank(&mut code, b, i, len, &mut line);
            i += len;
        } else if c == b'"' || (c == b'b' && i + 1 < b.len() && b[i + 1] == b'"') {
            let open = if c == b'b' { i + 1 } else { i };
            if c == b'b' {
                code.push(b' ');
            }
            let len = plain_string_len(b, open);
            blank(&mut code, b, open, len, &mut line);
            i = open + len;
        } else if c == b'\'' {
            if let Some(len) = char_literal_len(b, i) {
                blank(&mut code, b, i, len, &mut line);
                i += len;
            } else {
                // a lifetime: keep the quote and the identifier as code
                code.push(c);
                i += 1;
            }
        } else if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
            if let Some(len) = char_literal_len(b, i + 1) {
                code.push(b' ');
                blank(&mut code, b, i + 1, len, &mut line);
                i += 1 + len;
            } else {
                code.push(c);
                i += 1;
            }
        } else {
            code.push(c);
            i += 1;
        }
    }

    // masking only replaces ASCII bytes with spaces and copies the
    // rest verbatim, so the result is valid UTF-8 by construction
    let code = String::from_utf8_lossy(&code).into_owned();
    Masked { code, comments }
}

/// Push `len` bytes starting at `i` as blanks (newlines kept).
fn blank(code: &mut Vec<u8>, b: &[u8], i: usize, len: usize, line: &mut usize) {
    for &byte in &b[i..(i + len).min(b.len())] {
        if byte == b'\n' {
            code.push(b'\n');
            *line += 1;
        } else {
            code.push(b' ');
        }
    }
}

/// Length of a raw string literal (`r"…"`, `r#"…"#`, `br##"…"##`, …)
/// starting at `i`, or None if `i` does not start one.
fn raw_string_len(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    if j < b.len() && b[j] == b'b' {
        j += 1;
    }
    if j >= b.len() || b[j] != b'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    if j >= b.len() || b[j] != b'"' {
        return None;
    }
    j += 1;
    while j < b.len() {
        if b[j] == b'"' {
            let mut k = 0usize;
            while k < hashes && j + 1 + k < b.len() && b[j + 1 + k] == b'#' {
                k += 1;
            }
            if k == hashes {
                return Some(j + 1 + hashes - i);
            }
        }
        j += 1;
    }
    Some(b.len() - i)
}

/// Length of a plain `"…"` literal starting at the opening quote.
fn plain_string_len(b: &[u8], open: usize) -> usize {
    let mut j = open + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1 - open,
            _ => j += 1,
        }
    }
    b.len() - open
}

/// Length of a char literal starting at the quote, or None if this is
/// a lifetime (`'a`) rather than a literal (`'a'`, `'\n'`).
fn char_literal_len(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i + 1;
    if j >= b.len() {
        return None;
    }
    if b[j] == b'\\' {
        j += 2;
        // escapes can be multi-byte (\u{…}, \x41): scan to the quote
        while j < b.len() && b[j] != b'\'' && b[j] != b'\n' {
            j += 1;
        }
        if j < b.len() && b[j] == b'\'' {
            return Some(j + 1 - i);
        }
        return None;
    }
    // multi-byte UTF-8 scalar or single ASCII char, then a quote
    let mut j = i + 1;
    let first = b[j];
    let char_len = if first < 0x80 {
        1
    } else if first >= 0xf0 {
        4
    } else if first >= 0xe0 {
        3
    } else {
        2
    };
    j += char_len;
    if j < b.len() && b[j] == b'\'' && b[i + 1] != b'\'' {
        Some(j + 1 - i)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_are_blanked_and_collected() {
        let src = "let x = 1; // .unwrap() here is fine\nlet y = 2;\n";
        let m = mask(src);
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("let y = 2;"));
        assert_eq!(m.comments.len(), 1);
        assert_eq!(m.comments[0].line, 1);
        assert!(m.comments[0].text.contains(".unwrap() here is fine"));
        assert_eq!(m.code.len(), src.len());
    }

    #[test]
    fn nested_block_comments_and_doc_comments() {
        let src = "/* outer /* inner panic!() */ still out */ fn f() {}\n/// docs .expect()\nfn g() {}\n";
        let m = mask(src);
        assert!(!m.code.contains("panic"));
        assert!(!m.code.contains("expect"));
        assert!(m.code.contains("fn f() {}"));
        assert!(m.code.contains("fn g() {}"));
        assert_eq!(m.comments.len(), 2);
    }

    #[test]
    fn strings_raw_strings_and_chars_are_blanked() {
        let src = r##"let a = "call .unwrap() now"; let b = r#"panic!("x")"#; let c = '"'; let d = b"todo!()";"##;
        let m = mask(src);
        assert!(!m.code.contains("unwrap"));
        assert!(!m.code.contains("panic"));
        assert!(!m.code.contains("todo"));
        assert!(m.code.contains("let a ="));
        assert!(m.code.contains("let d ="));
    }

    #[test]
    fn lifetimes_survive_but_char_literals_do_not() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = 'x'; let nl = '\\n'; c }\n";
        let m = mask(src);
        assert!(m.code.contains("<'a>"), "lifetime must stay: {}", m.code);
        assert!(m.code.contains("&'a str"));
        assert!(!m.code.contains("'x'"));
        assert!(!m.code.contains("\\n"));
    }

    #[test]
    fn multiline_strings_keep_line_structure() {
        let src = "let s = \"one\ntwo .unwrap()\nthree\";\nlet t = 5;\n";
        let m = mask(src);
        assert_eq!(
            m.code.matches('\n').count(),
            src.matches('\n').count(),
            "newline count must survive masking"
        );
        assert!(!m.code.contains("unwrap"));
        assert!(m.code.contains("let t = 5;"));
    }

    #[test]
    fn escaped_quotes_do_not_terminate_strings() {
        let src = r#"let s = "a \" b .expect( c"; let x = 1;"#;
        let m = mask(src);
        assert!(!m.code.contains("expect"));
        assert!(m.code.contains("let x = 1;"));
    }
}
