//! LIBSVM-format reader.
//!
//! The paper's real datasets (COV1, ASTRO-PH) are distributed in LIBSVM
//! format; when the files are available, `load` gives the exact original
//! data path and the synthetic substitutes in [`super::synthetic`] are
//! bypassed. Labels are coerced to {-1, +1} for classification losses
//! (anything <= 0 maps to -1).

use super::Dataset;
use crate::linalg::{CsrMatrix, DataMatrix};
use crate::{Error, Result};
use std::io::BufRead;
use std::path::Path;

/// Parse a LIBSVM file: `label [qid:N] idx:val idx:val ...` per line,
/// 1-based indices. `#` comment lines (and `#`-introduced trailing
/// comments, per the LIBSVM tools convention) are skipped, as are
/// ranking `qid:` tokens — the group id has no feature column. `dim`
/// pads/overrides the inferred feature dimension (0 = infer from the
/// data).
pub fn load(path: &Path, dim: usize) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    parse(reader.lines().map(|l| l.map_err(Error::from)), dim, path.display())
}

/// One data line, parsed: coerced {-1,+1} label plus (1-based index,
/// value) features; `None` for comment / blank lines. Shared by the
/// whole-file [`parse`] and the by-reference [`load_rows`] so both
/// paths run the identical per-token `str -> f64` parses — the
/// bit-exactness contract between Init-by-value and Init-by-ref shards.
fn parse_data_line(line: &str, lineno: usize) -> Result<Option<(f64, Vec<(usize, f64)>)>> {
    // `#` starts a comment: a whole comment line, or a trailing
    // comment after the features (LIBSVM tools emit both).
    let line = line.split('#').next().unwrap_or("").trim();
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_ascii_whitespace();
    let label: f64 = parts
        .next()
        .ok_or_else(|| bad(lineno, "missing label"))?
        .parse()
        .map_err(|_| bad(lineno, "unparseable label"))?;
    let label = if label > 0.0 { 1.0 } else { -1.0 };
    let mut feats = Vec::new();
    for tok in parts {
        let (idx, val) = tok
            .split_once(':')
            .ok_or_else(|| bad(lineno, "feature not idx:val"))?;
        // Ranking files carry a query-group token (`qid:7`) between
        // the label and the features; it names no feature column,
        // so it is validated and skipped.
        if idx == "qid" {
            val.parse::<u64>()
                .map_err(|_| bad(lineno, "bad qid value"))?;
            continue;
        }
        let idx: usize = idx.parse().map_err(|_| bad(lineno, "bad feature index"))?;
        if idx == 0 {
            return Err(bad(lineno, "indices are 1-based"));
        }
        let val: f64 = val.parse().map_err(|_| bad(lineno, "bad feature value"))?;
        feats.push((idx, val));
    }
    Ok(Some((label, feats)))
}

/// Parse from any line iterator (unit tests feed strings).
pub fn parse<I, D>(lines: I, dim: usize, origin: D) -> Result<Dataset>
where
    I: Iterator<Item = Result<String>>,
    D: std::fmt::Display,
{
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        let Some((label, feats)) = parse_data_line(&line, lineno)? else {
            continue;
        };
        let row = y.len();
        y.push(label);
        for (idx, val) in feats {
            max_col = max_col.max(idx);
            trips.push((row, idx - 1, val));
        }
    }
    if y.is_empty() {
        return Err(Error::Config(format!("{origin}: empty libsvm input")));
    }
    let d = if dim > 0 {
        if max_col > dim {
            return Err(Error::Config(format!(
                "{origin}: feature index {max_col} exceeds requested dim {dim}"
            )));
        }
        dim
    } else {
        max_col
    };
    let x = CsrMatrix::from_triplets(y.len(), d, &trips);
    Ok(Dataset::new(
        format!("libsvm:{origin}"),
        DataMatrix::Sparse(x),
        y,
    ))
}

/// Byte-offset index of a LIBSVM file: where every *data* row starts
/// (comment and blank lines excluded) and its 0-based line number (so
/// errors attribute the same line as a whole-file [`load`]). One
/// sequential scan, O(1) per row thereafter — the piece that lets a
/// by-reference worker read only its own shard's lines.
pub struct LineIndex {
    /// (byte offset of line start, 0-based line number) per data row.
    entries: Vec<(u64, usize)>,
}

impl LineIndex {
    /// Number of data rows in the file.
    pub fn rows(&self) -> usize {
        self.entries.len()
    }

    /// Scan the file once, recording where every data row starts.
    pub fn build(path: &Path) -> Result<LineIndex> {
        let file = std::fs::File::open(path)?;
        let mut reader = std::io::BufReader::new(file);
        let mut entries = Vec::new();
        let mut offset = 0u64;
        let mut lineno = 0usize;
        let mut line = String::new();
        loop {
            line.clear();
            let n = reader.read_line(&mut line)?;
            if n == 0 {
                break;
            }
            let content = line.split('#').next().unwrap_or("").trim();
            if !content.is_empty() {
                entries.push((offset, lineno));
            }
            offset += n as u64;
            lineno += 1;
        }
        Ok(LineIndex { entries })
    }
}

/// Load only the given rows (0-based data-row indices, any order,
/// duplicates allowed) of a LIBSVM file — the worker half of
/// Init-by-reference. Bit-identical to `load(path, dim)` followed by
/// `take_rows(rows)`: the same [`parse_data_line`] runs on the same
/// bytes, and rows are assembled in the caller's (shuffled-shard)
/// order. `dim` must be the full dataset's feature dimension (> 0) — a
/// row subset cannot infer it, so the leader ships its authoritative
/// value in the `InitRef` payload.
pub fn load_rows(path: &Path, dim: usize, rows: &[usize]) -> Result<(CsrMatrix, Vec<f64>)> {
    use std::io::{Seek, SeekFrom};
    if dim == 0 {
        return Err(Error::Config(format!(
            "{}: load_rows needs the dataset's full dim (0 = infer is whole-file only)",
            path.display()
        )));
    }
    let index = LineIndex::build(path)?;
    let n = index.rows();
    for &r in rows {
        if r >= n {
            return Err(Error::Config(format!(
                "{}: shard row {r} out of range ({n} data rows)",
                path.display()
            )));
        }
    }
    // Parse each distinct wanted row once, in file order (forward seeks
    // only), then assemble in the caller's order below.
    let mut uniq: Vec<usize> = rows.to_vec();
    uniq.sort_unstable();
    uniq.dedup();
    let file = std::fs::File::open(path)?;
    let mut reader = std::io::BufReader::new(file);
    let mut line = String::new();
    let mut parsed: std::collections::HashMap<usize, (f64, Vec<(usize, f64)>)> =
        std::collections::HashMap::with_capacity(uniq.len());
    for r in uniq {
        let (off, lineno) = index.entries[r];
        reader.seek(SeekFrom::Start(off))?;
        line.clear();
        reader.read_line(&mut line)?;
        let row = parse_data_line(&line, lineno)?
            .ok_or_else(|| bad(lineno, "indexed data row changed under the reader"))?;
        parsed.insert(r, row);
    }
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut y = Vec::with_capacity(rows.len());
    for (p, r) in rows.iter().enumerate() {
        let (label, feats) = &parsed[r];
        y.push(*label);
        for &(idx, val) in feats {
            if idx > dim {
                return Err(Error::Config(format!(
                    "{}: feature index {idx} exceeds requested dim {dim}",
                    path.display()
                )));
            }
            trips.push((p, idx - 1, val));
        }
    }
    Ok((CsrMatrix::from_triplets(rows.len(), dim, &trips), y))
}

fn bad(lineno: usize, what: &str) -> Error {
    Error::Config(format!("libsvm line {}: {what}", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(s: &str) -> impl Iterator<Item = Result<String>> + '_ {
        s.lines().map(|l| Ok(l.to_string()))
    }

    #[test]
    fn parses_basic_file() {
        let ds = parse(
            lines("+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 3:1.5"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0, 0.0]), 0.5);
        assert_eq!(ds.x.row_dot(0, &[0.0, 0.0, 1.0]), 2.0);
    }

    #[test]
    fn comment_lines_and_trailing_comments_skipped() {
        let ds = parse(
            lines("# header comment\n+1 1:0.5 # trailing note 9:9\n  # indented\n-1 2:1.0"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2, "commented-out features must not widen the data");
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0]), 0.5);
    }

    #[test]
    fn qid_tokens_are_skipped_not_features() {
        let ds = parse(
            lines("+1 qid:1 1:0.5 3:2.0\n-1 qid:1 2:1.0\n+1 qid:2 1:0.25"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3, "qid must not become a feature column");
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0, 0.0]), 0.5);
        assert_eq!(ds.x.row_dot(2, &[1.0, 0.0, 0.0]), 0.25);
        // malformed qid values are rejected, not silently dropped
        assert!(parse(lines("+1 qid:x 1:1"), 0, "t").is_err());
    }

    #[test]
    fn dimension_inferred_from_data_when_dim_is_zero() {
        let ds = parse(lines("+1 7:1.0\n-1 2:1.0"), 0, "test").unwrap();
        assert_eq!(ds.d(), 7, "dim 0 must infer the max 1-based index");
        // and inference composes with qid/comments
        let ds = parse(lines("+1 qid:3 5:1.0 # tail\n-1 2:1.0"), 0, "test").unwrap();
        assert_eq!(ds.d(), 5);
    }

    #[test]
    fn label_coercion() {
        let ds = parse(lines("0 1:1\n2 1:1\n-3 1:1"), 0, "test").unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn dim_override() {
        let ds = parse(lines("+1 2:1.0"), 10, "test").unwrap();
        assert_eq!(ds.d(), 10);
        assert!(parse(lines("+1 12:1.0"), 10, "test").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(lines("notanum 1:1"), 0, "t").is_err());
        assert!(parse(lines("+1 0:1"), 0, "t").is_err());
        assert!(parse(lines("+1 1"), 0, "t").is_err());
        assert!(parse(lines(""), 0, "t").is_err());
    }

    /// A file exercising every line shape the parser knows: comments,
    /// blank lines, trailing comments, qid tokens, exponent-format
    /// values (bit-exactness hinges on parsing the identical token).
    const MIXED: &str = "# header\n\
        +1 qid:1 1:0.5 3:2.0e-1\n\
        \n\
        -1 2:1.25 # trailing 9:9\n\
        0 1:3.0 4:-0.75\n\
        +2 qid:3 2:1e3\n\
        -1 3:0.1\n";

    fn write_mixed() -> (crate::util::tempdir::TempDir, std::path::PathBuf) {
        let dir = crate::util::tempdir::TempDir::new("libsvm").unwrap();
        let p = dir.path().join("mixed.svm");
        std::fs::write(&p, MIXED).unwrap();
        (dir, p)
    }

    #[test]
    fn line_index_counts_data_rows() {
        let (_dir, p) = write_mixed();
        let idx = LineIndex::build(&p).unwrap();
        assert_eq!(idx.rows(), 5);
    }

    #[test]
    fn load_rows_is_bit_identical_to_load_plus_take_rows() {
        let (_dir, p) = write_mixed();
        let full = load(&p, 4).unwrap();
        // shuffled order with a duplicate: exactly take_rows semantics
        let rows = [3usize, 0, 4, 0, 2];
        let (x, y) = load_rows(&p, 4, &rows).unwrap();
        let DataMatrix::Sparse(reference) = full.x.take_rows(&rows) else {
            panic!("libsvm loads sparse");
        };
        assert_eq!(x, reference, "CSR structure and bits must match take_rows");
        let want_y: Vec<f64> = rows.iter().map(|&r| full.y[r]).collect();
        assert_eq!(y, want_y);
    }

    #[test]
    fn load_rows_rejects_bad_inputs() {
        let (_dir, p) = write_mixed();
        // row out of range
        assert!(load_rows(&p, 4, &[5]).is_err());
        // dim must be explicit for a subset
        assert!(load_rows(&p, 0, &[0]).is_err());
        // dim too small for a loaded row's features
        assert!(load_rows(&p, 2, &[3]).is_err());
        // malformed line inside the subset surfaces as Err
        let bad = p.with_file_name("bad.svm");
        std::fs::write(&bad, "+1 1:0.5\n+1 0:1\n").unwrap();
        assert!(load_rows(&bad, 4, &[1]).is_err());
        assert!(load_rows(&bad, 4, &[0]).is_ok(), "good rows stay loadable");
    }
}
