//! LIBSVM-format reader.
//!
//! The paper's real datasets (COV1, ASTRO-PH) are distributed in LIBSVM
//! format; when the files are available, `load` gives the exact original
//! data path and the synthetic substitutes in [`super::synthetic`] are
//! bypassed. Labels are coerced to {-1, +1} for classification losses
//! (anything <= 0 maps to -1).

use super::Dataset;
use crate::linalg::{CsrMatrix, DataMatrix};
use crate::{Error, Result};
use std::io::BufRead;
use std::path::Path;

/// Parse a LIBSVM file: `label [qid:N] idx:val idx:val ...` per line,
/// 1-based indices. `#` comment lines (and `#`-introduced trailing
/// comments, per the LIBSVM tools convention) are skipped, as are
/// ranking `qid:` tokens — the group id has no feature column. `dim`
/// pads/overrides the inferred feature dimension (0 = infer from the
/// data).
pub fn load(path: &Path, dim: usize) -> Result<Dataset> {
    let file = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(file);
    parse(reader.lines().map(|l| l.map_err(Error::from)), dim, path.display())
}

/// Parse from any line iterator (unit tests feed strings).
pub fn parse<I, D>(lines: I, dim: usize, origin: D) -> Result<Dataset>
where
    I: Iterator<Item = Result<String>>,
    D: std::fmt::Display,
{
    let mut trips: Vec<(usize, usize, f64)> = Vec::new();
    let mut y = Vec::new();
    let mut max_col = 0usize;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        // `#` starts a comment: a whole comment line, or a trailing
        // comment after the features (LIBSVM tools emit both).
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_ascii_whitespace();
        let label: f64 = parts
            .next()
            .ok_or_else(|| bad(lineno, "missing label"))?
            .parse()
            .map_err(|_| bad(lineno, "unparseable label"))?;
        let row = y.len();
        y.push(if label > 0.0 { 1.0 } else { -1.0 });
        for tok in parts {
            let (idx, val) = tok
                .split_once(':')
                .ok_or_else(|| bad(lineno, "feature not idx:val"))?;
            // Ranking files carry a query-group token (`qid:7`) between
            // the label and the features; it names no feature column,
            // so it is validated and skipped.
            if idx == "qid" {
                val.parse::<u64>()
                    .map_err(|_| bad(lineno, "bad qid value"))?;
                continue;
            }
            let idx: usize =
                idx.parse().map_err(|_| bad(lineno, "bad feature index"))?;
            if idx == 0 {
                return Err(bad(lineno, "indices are 1-based"));
            }
            let val: f64 =
                val.parse().map_err(|_| bad(lineno, "bad feature value"))?;
            max_col = max_col.max(idx);
            trips.push((row, idx - 1, val));
        }
    }
    if y.is_empty() {
        return Err(Error::Config(format!("{origin}: empty libsvm input")));
    }
    let d = if dim > 0 {
        if max_col > dim {
            return Err(Error::Config(format!(
                "{origin}: feature index {max_col} exceeds requested dim {dim}"
            )));
        }
        dim
    } else {
        max_col
    };
    let x = CsrMatrix::from_triplets(y.len(), d, &trips);
    Ok(Dataset::new(
        format!("libsvm:{origin}"),
        DataMatrix::Sparse(x),
        y,
    ))
}

fn bad(lineno: usize, what: &str) -> Error {
    Error::Config(format!("libsvm line {}: {what}", lineno + 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(s: &str) -> impl Iterator<Item = Result<String>> + '_ {
        s.lines().map(|l| Ok(l.to_string()))
    }

    #[test]
    fn parses_basic_file() {
        let ds = parse(
            lines("+1 1:0.5 3:2.0\n-1 2:1.0\n# comment\n\n+1 3:1.5"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0, 0.0]), 0.5);
        assert_eq!(ds.x.row_dot(0, &[0.0, 0.0, 1.0]), 2.0);
    }

    #[test]
    fn comment_lines_and_trailing_comments_skipped() {
        let ds = parse(
            lines("# header comment\n+1 1:0.5 # trailing note 9:9\n  # indented\n-1 2:1.0"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 2);
        assert_eq!(ds.d(), 2, "commented-out features must not widen the data");
        assert_eq!(ds.y, vec![1.0, -1.0]);
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0]), 0.5);
    }

    #[test]
    fn qid_tokens_are_skipped_not_features() {
        let ds = parse(
            lines("+1 qid:1 1:0.5 3:2.0\n-1 qid:1 2:1.0\n+1 qid:2 1:0.25"),
            0,
            "test",
        )
        .unwrap();
        assert_eq!(ds.n(), 3);
        assert_eq!(ds.d(), 3, "qid must not become a feature column");
        assert_eq!(ds.x.row_dot(0, &[1.0, 0.0, 0.0]), 0.5);
        assert_eq!(ds.x.row_dot(2, &[1.0, 0.0, 0.0]), 0.25);
        // malformed qid values are rejected, not silently dropped
        assert!(parse(lines("+1 qid:x 1:1"), 0, "t").is_err());
    }

    #[test]
    fn dimension_inferred_from_data_when_dim_is_zero() {
        let ds = parse(lines("+1 7:1.0\n-1 2:1.0"), 0, "test").unwrap();
        assert_eq!(ds.d(), 7, "dim 0 must infer the max 1-based index");
        // and inference composes with qid/comments
        let ds = parse(lines("+1 qid:3 5:1.0 # tail\n-1 2:1.0"), 0, "test").unwrap();
        assert_eq!(ds.d(), 5);
    }

    #[test]
    fn label_coercion() {
        let ds = parse(lines("0 1:1\n2 1:1\n-3 1:1"), 0, "test").unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0, -1.0]);
    }

    #[test]
    fn dim_override() {
        let ds = parse(lines("+1 2:1.0"), 10, "test").unwrap();
        assert_eq!(ds.d(), 10);
        assert!(parse(lines("+1 12:1.0"), 10, "test").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse(lines("notanum 1:1"), 0, "t").is_err());
        assert!(parse(lines("+1 0:1"), 0, "t").is_err());
        assert!(parse(lines("+1 1"), 0, "t").is_err());
        assert!(parse(lines(""), 0, "t").is_err());
    }
}
