//! Random even sharding — the paper's "N = nm samples evenly and randomly
//! distributed among the machines".
//!
//! The split is a uniformly random partition: a seeded Fisher-Yates
//! shuffle of the row indices, cut into m nearly-equal contiguous chunks
//! (sizes differ by at most 1). Determinism under a fixed seed is part of
//! the contract — every experiment in EXPERIMENTS.md records its seed.

use super::{Dataset, Shard};
use crate::util::Rng64;

/// Assign row indices to m shards. Returned as per-shard index lists.
pub fn shard_indices(n: usize, m: usize, seed: u64) -> Vec<Vec<usize>> {
    assert!(m >= 1, "need at least one shard");
    assert!(n >= m, "fewer samples ({n}) than shards ({m})");
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = Rng64::seed_from_u64(seed);
    rng.shuffle(&mut idx);

    // First (n % m) shards get one extra sample.
    let base = n / m;
    let extra = n % m;
    let mut out = Vec::with_capacity(m);
    let mut pos = 0;
    for i in 0..m {
        let take = base + usize::from(i < extra);
        out.push(idx[pos..pos + take].to_vec());
        pos += take;
    }
    debug_assert_eq!(pos, n);
    out
}

/// Split a dataset into m shards by random even partition.
pub fn shard_dataset(ds: &Dataset, m: usize, seed: u64) -> Vec<Shard> {
    shard_indices(ds.n(), m, seed)
        .into_iter()
        .map(|rows| {
            let x = ds.x.take_rows(&rows);
            let y = rows.iter().map(|&i| ds.y[i]).collect();
            Shard::new(x, y)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{DataMatrix, DenseMatrix};

    #[test]
    fn partition_is_exact() {
        let parts = shard_indices(103, 8, 7);
        assert_eq!(parts.len(), 8);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1);
    }

    #[test]
    fn deterministic_under_seed() {
        assert_eq!(shard_indices(50, 4, 9), shard_indices(50, 4, 9));
        assert_ne!(shard_indices(50, 4, 9), shard_indices(50, 4, 10));
    }

    #[test]
    fn shards_carry_matching_rows() {
        let x = DenseMatrix::from_rows(&[
            vec![0.0],
            vec![1.0],
            vec![2.0],
            vec![3.0],
            vec![4.0],
        ]);
        let ds = crate::data::Dataset::new(
            "t",
            DataMatrix::Dense(x),
            vec![0.0, 10.0, 20.0, 30.0, 40.0],
        );
        let shards = shard_dataset(&ds, 2, 1);
        for s in &shards {
            for i in 0..s.n() {
                // y was constructed as 10 * x value: sharding must keep
                // rows and targets aligned.
                assert_eq!(s.y[i], 10.0 * s.x.row_dot(i, &[1.0]));
            }
        }
        assert_eq!(shards[0].n() + shards[1].n(), 5);
    }

    #[test]
    #[should_panic(expected = "fewer samples")]
    fn rejects_more_shards_than_rows() {
        shard_indices(3, 5, 0);
    }
}
