//! Synthetic dataset generators.
//!
//! `synthetic_fig2` is the paper's own synthetic model, verbatim. The
//! other three substitute for COV1 / ASTRO-PH / MNIST-47 (see DESIGN.md §5
//! for the substitution argument): what figs. 3-4 exercise is the
//! interplay of condition number and shard-to-shard Hessian concentration
//! as n = N/m shrinks, so the generators match the originals on
//! dimensionality, sparsity, class balance and separability rather than on
//! raw bytes.

use super::Dataset;
use crate::linalg::{CsrMatrix, DataMatrix, DenseMatrix};
use crate::util::Rng64;

/// The paper's fig. 2 model: `y = <x, w*> + xi`, `x ~ N(0, Sigma)` with
/// diagonal `Sigma_ii = i^{-1.2}` (1-indexed), `xi ~ N(0, 1)`, `w* = 1`.
///
/// d = 500 in the paper; `reg` is the ridge coefficient (paper: 0.005 —
/// note the paper writes the objective as mean *squared* error + 0.005 w^2;
/// our ridge is (1/2n)||.||^2 + (lam/2)||w||^2, so lam = 2 * 0.005 = 0.01
/// reproduces the identical minimizer. `synthetic_fig2` takes the paper's
/// coefficient and performs that conversion internally).
pub fn synthetic_fig2(n: usize, d: usize, paper_reg: f64, seed: u64) -> Dataset {
    let mut rng = Rng64::seed_from_u64(seed);
    let sigma: Vec<f64> = (1..=d).map(|i| (i as f64).powf(-1.2).sqrt()).collect();
    let w_star = vec![1.0; d];

    let mut x = DenseMatrix::zeros(n, d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let row = x.row_mut(i);
        for j in 0..d {
            row[j] = rng.normal() * sigma[j];
        }
        let mean: f64 = row.iter().zip(&w_star).map(|(a, b)| a * b).sum();
        y.push(mean + rng.normal());
    }
    let mut ds = Dataset::new(
        format!("fig2-n{n}-d{d}"),
        DataMatrix::Dense(x),
        y,
    );
    // Stash the equivalent lambda for our ridge parameterization; callers
    // read it via `fig2_lambda`.
    ds.name = format!("fig2-n{n}-d{d}-lam{}", 2.0 * paper_reg);
    ds
}

/// Our ridge lambda equivalent to the paper's fig. 2 regularizer 0.005.
pub fn fig2_lambda(paper_reg: f64) -> f64 {
    2.0 * paper_reg
}

/// COV1-like: d = 54 dense cartographic-style features (mixed continuous +
/// binary), moderately separable binary labels, ~majority-class skew as in
/// covertype class-1-vs-rest.
pub fn covtype_like(n: usize, n_test: usize, seed: u64) -> Dataset {
    let d = 54;
    let mut rng = Rng64::seed_from_u64(seed);
    let teacher = sample_unit_teacher(d, &mut rng);
    let gen = |n: usize, rng: &mut Rng64| {
        let mut x = DenseMatrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let row = x.row_mut(i);
            // 10 continuous features, 44 sparse binary indicator-ish ones
            for j in 0..10 {
                row[j] = rng.normal();
            }
            for j in 10..d {
                row[j] = if rng.bool(0.15) { 1.0 } else { 0.0 };
            }
            let margin: f64 =
                row.iter().zip(&teacher).map(|(a, b)| a * b).sum::<f64>();
            // label noise 10%, slight class skew via threshold shift
            let clean = if margin + 0.2 > 0.0 { 1.0 } else { -1.0 };
            y.push(if rng.bool(0.10) { -clean } else { clean });
        }
        (x, y)
    };
    let (x, y) = gen(n, &mut rng);
    let (tx, ty) = gen(n_test, &mut rng);
    Dataset::new("cov1-like", DataMatrix::Dense(x), y)
        .with_test(DataMatrix::Dense(tx), ty)
}

/// ASTRO-PH-like: high-dimensional sparse bag-of-words-style features
/// (d = 10_000, ~50 nnz/row with power-law column popularity, tf-style
/// positive values, L2-normalized rows), nearly separable labels — the
/// regime where the real ASTRO-PH (d ~ 99k, avg 77 nnz) lives.
pub fn astro_like(n: usize, n_test: usize, seed: u64) -> Dataset {
    let d = 10_000;
    let nnz_per_row = 50;
    let mut rng = Rng64::seed_from_u64(seed);
    // Power-law column sampler: popularity ~ 1 / (k+10)^0.9
    let weights: Vec<f64> =
        (0..d).map(|k| 1.0 / ((k + 10) as f64).powf(0.9)).collect();
    let cum: Vec<f64> = weights
        .iter()
        .scan(0.0, |acc, w| {
            *acc += w;
            Some(*acc)
        })
        .collect();
    let teacher = sample_unit_teacher(d, &mut rng);

    let gen = |n: usize, rng: &mut Rng64| {
        let mut trips = Vec::with_capacity(n * nnz_per_row);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let mut cols = std::collections::BTreeMap::new();
            for _ in 0..nnz_per_row {
                let j = rng.weighted_index(&cum).min(d - 1);
                *cols.entry(j).or_insert(0.0) += 1.0;
            }
            // L2-normalize the row (tf counts -> unit vector)
            let norm: f64 =
                cols.values().map(|v: &f64| v * v).sum::<f64>().sqrt();
            let mut margin = 0.0;
            for (&j, &v) in &cols {
                let val = v / norm;
                trips.push((i, j, val));
                margin += val * teacher[j];
            }
            let clean = if margin > 0.0 { 1.0 } else { -1.0 };
            y.push(if rng.bool(0.03) { -clean } else { clean });
        }
        (CsrMatrix::from_triplets(n, d, &trips), y)
    };
    let (x, y) = gen(n, &mut rng);
    let (tx, ty) = gen(n_test, &mut rng);
    Dataset::new("astro-like", DataMatrix::Sparse(x), y)
        .with_test(DataMatrix::Sparse(tx), ty)
}

/// MNIST-4v7-like: d = 784 dense "pixel" features. Two anisotropic
/// Gaussian class-conditionals with a shared low-rank covariance and a
/// clear mean separation (4-vs-7 is one of the easier MNIST pairs); pixel
/// values clipped to [0, 1] like normalized grayscale.
pub fn mnist47_like(n: usize, n_test: usize, seed: u64) -> Dataset {
    let d = 784;
    let rank = 20;
    let mut rng = Rng64::seed_from_u64(seed);

    // Shared structure: two mean "templates" + low-rank directions.
    let mu_pos: Vec<f64> = (0..d).map(|j| template(j, 0)).collect();
    let mu_neg: Vec<f64> = (0..d).map(|j| template(j, 1)).collect();
    let dirs: Vec<Vec<f64>> = (0..rank)
        .map(|_| {
            let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let nrm = v.iter().map(|a| a * a).sum::<f64>().sqrt();
            v.into_iter().map(|a| 0.08 * a / nrm).collect()
        })
        .collect();

    let gen = |n: usize, rng: &mut Rng64| {
        let mut x = DenseMatrix::zeros(n, d);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let label = rng.sign();
            let mu = if label > 0.0 { &mu_pos } else { &mu_neg };
            let coeffs: Vec<f64> = (0..rank).map(|_| rng.normal()).collect();
            let row = x.row_mut(i);
            for j in 0..d {
                let mut v = mu[j] + 0.05 * rng.normal();
                for (k, dir) in dirs.iter().enumerate() {
                    v += coeffs[k] * dir[j];
                }
                row[j] = v.clamp(0.0, 1.0);
            }
            y.push(label);
        }
        (x, y)
    };
    let (x, y) = gen(n, &mut rng);
    let (tx, ty) = gen(n_test, &mut rng);
    Dataset::new("mnist47-like", DataMatrix::Dense(x), y)
        .with_test(DataMatrix::Dense(tx), ty)
}

/// Smooth blob "digit template" j-th pixel for class c, on a 28x28 grid.
fn template(j: usize, class: usize) -> f64 {
    let (r, c) = ((j / 28) as f64, (j % 28) as f64);
    let (cr, cc, s) = if class == 0 {
        (10.0, 10.0, 5.0) // blob upper-left-ish
    } else {
        (18.0, 18.0, 6.0) // blob lower-right-ish
    };
    let dist2 = (r - cr) * (r - cr) + (c - cc) * (c - cc);
    0.8 * (-dist2 / (2.0 * s * s)).exp()
}

/// High-dimensional sparse ridge instance: each row has `nnz_per_row`
/// nonzero columns (uniform, duplicates summed), standard-normal
/// values; `y = <x, w*> + 0.1 xi` with a unit teacher. The regime the
/// paper's sparse datasets live in (d up to ~10^5, a handful of
/// features per row) where a dense d x d Gram is unbuildable — the
/// workload for the matrix-free local-solve path and the `scale`
/// benches/tests. No test split.
pub fn sparse_ridge(n: usize, d: usize, nnz_per_row: usize, seed: u64) -> Dataset {
    assert!(d > 0 && nnz_per_row > 0, "sparse_ridge needs d, nnz >= 1");
    let mut rng = Rng64::seed_from_u64(seed);
    let teacher = sample_unit_teacher(d, &mut rng);
    let mut trips = Vec::with_capacity(n * nnz_per_row);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let mut cols = std::collections::BTreeMap::new();
        for _ in 0..nnz_per_row {
            let j = rng.below(d);
            *cols.entry(j).or_insert(0.0) += rng.normal();
        }
        let mut mean = 0.0;
        for (&j, &v) in &cols {
            trips.push((i, j, v));
            mean += v * teacher[j];
        }
        y.push(mean + 0.1 * rng.normal());
    }
    Dataset::new(
        format!("sparse-ridge-n{n}-d{d}"),
        DataMatrix::Sparse(CsrMatrix::from_triplets(n, d, &trips)),
        y,
    )
}

fn sample_unit_teacher(d: usize, rng: &mut Rng64) -> Vec<f64> {
    let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    let nrm = v.iter().map(|a| a * a).sum::<f64>().sqrt();
    v.into_iter().map(|a| a / nrm).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shapes_and_determinism() {
        let a = synthetic_fig2(100, 20, 0.005, 3);
        let b = synthetic_fig2(100, 20, 0.005, 3);
        assert_eq!(a.n(), 100);
        assert_eq!(a.d(), 20);
        assert_eq!(a.y, b.y);
        assert_eq!(fig2_lambda(0.005), 0.01);
    }

    #[test]
    fn fig2_covariance_decays() {
        // Column variance should roughly follow i^-1.2 (the paper's
        // Sigma_ii). With n = 4000 Gaussian samples each variance
        // estimate has relative std sqrt(2/n) ~ 2.2%, so the ratio
        // v9/v0 (expected 10^-1.2 ~ 0.063) is measured to ~ +-0.002;
        // the 0.02 absolute tolerance is ~10 sigma on this pinned seed.
        let ds = synthetic_fig2(4000, 10, 0.005, 11);
        let x = ds.x.to_dense();
        let var = |j: usize| -> f64 {
            let mut s = 0.0;
            for i in 0..x.rows() {
                s += x.get(i, j) * x.get(i, j);
            }
            s / x.rows() as f64
        };
        let v0 = var(0);
        let v9 = var(9);
        let expect_ratio = (10.0f64).powf(-1.2);
        assert!((v9 / v0 - expect_ratio).abs() < 0.02, "{} vs {}", v9 / v0, expect_ratio);
        // ...and the decay is strictly monotone in expectation end-to-end.
        assert!(v9 < v0, "{v9} vs {v0}");
    }

    #[test]
    fn covtype_like_shapes() {
        let ds = covtype_like(200, 50, 5);
        assert_eq!(ds.d(), 54);
        assert_eq!(ds.n(), 200);
        assert_eq!(ds.test_shard().unwrap().n(), 50);
        assert!(ds.y.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn astro_like_is_sparse_and_normalized() {
        let ds = astro_like(100, 10, 7);
        assert_eq!(ds.d(), 10_000);
        if let DataMatrix::Sparse(s) = &ds.x {
            assert!(s.nnz() <= 100 * 50);
            assert!(s.nnz() >= 100 * 10);
            // rows unit-normalized
            let (idx, val) = s.row(0);
            assert!(!idx.is_empty());
            let nrm: f64 = val.iter().map(|v| v * v).sum::<f64>().sqrt();
            assert!((nrm - 1.0).abs() < 1e-9);
        } else {
            panic!("astro-like must be sparse");
        }
    }

    #[test]
    fn mnist47_like_pixel_range() {
        let ds = mnist47_like(50, 10, 13);
        assert_eq!(ds.d(), 784);
        let x = ds.x.to_dense();
        for i in 0..50 {
            for &v in x.row(i) {
                assert!((0.0..=1.0).contains(&v));
            }
        }
    }

    #[test]
    fn sparse_ridge_shapes_and_determinism() {
        let a = sparse_ridge(120, 5000, 3, 9);
        let b = sparse_ridge(120, 5000, 3, 9);
        assert_eq!(a.n(), 120);
        assert_eq!(a.d(), 5000);
        assert!(a.test_shard().is_none());
        assert_eq!(a.y, b.y);
        let DataMatrix::Sparse(s) = &a.x else { panic!("must be sparse") };
        assert!(s.nnz() <= 120 * 3, "nnz {}", s.nnz());
        assert!(s.nnz() >= 120, "nnz {}", s.nnz());
        // bit-equal matrices under the same seed
        let DataMatrix::Sparse(s2) = &b.x else { panic!() };
        assert_eq!(s, s2);
    }

    #[test]
    fn classes_roughly_balanced() {
        // Labels are fair coin flips: pos ~ Binomial(400, 0.5), std = 10.
        // The (100, 300) window is +-10 sigma around the mean — loose
        // enough to be seed-proof while still catching any systematic
        // class skew in the generator.
        let ds = mnist47_like(400, 10, 19);
        let pos = ds.y.iter().filter(|&&v| v > 0.0).count();
        assert!(pos > 100 && pos < 300, "pos={pos}");
    }
}
