//! The Theorem-1 construction: a 1-d stochastic problem where one-shot
//! parameter averaging provably cannot beat a single machine.
//!
//! `f(w; z) = lam * (w^2/2 + exp(w)) - z w`, with `z ~ N(0, 1)`.
//!
//! The empirical minimizer over n samples solves
//! `lam * sqrt(n) * (w + exp(w)) = z~` where `z~ = sum z_j / sqrt(n)` is
//! again standard normal; the population optimum solves `w + exp(w) = 0`
//! (w* = -0.567143..., minus the omega constant). Appendix A shows
//! `E[w_hat_1]` is biased below w* by Theta(1/(lam sqrt(n))) — averaging m
//! independent copies reduces variance but not this bias, which is what
//! the `thm1_osa_bound` bench measures.

use crate::util::Rng64;

/// Population optimum of f: the root of w + e^w = 0.
pub const W_STAR: f64 = -0.567_143_290_409_783_8;

/// Solve `lam * sqrt(n) * (w + exp(w)) = target` for w by Newton with a
/// bisection fallback; the LHS is strictly increasing so the root is
/// unique. This *is* the per-machine ERM for this construction.
pub fn solve_machine_erm(lam: f64, n: usize, target: f64) -> f64 {
    let c = lam * (n as f64).sqrt();
    let g = |w: f64| c * (w + w.exp()) - target;
    // Bracket the root.
    let (mut lo, mut hi) = (-1.0, 1.0);
    while g(lo) > 0.0 {
        lo *= 2.0;
        if lo < -1e6 {
            break;
        }
    }
    while g(hi) < 0.0 {
        hi *= 2.0;
        if hi > 1e6 {
            break;
        }
    }
    // Newton from the midpoint, guarded by the bracket.
    let mut w = 0.5 * (lo + hi);
    for _ in 0..200 {
        let gv = g(w);
        if gv.abs() < 1e-14 {
            break;
        }
        if gv > 0.0 {
            hi = w;
        } else {
            lo = w;
        }
        let dg = c * (1.0 + w.exp());
        let mut w_new = w - gv / dg;
        if !(lo..=hi).contains(&w_new) {
            w_new = 0.5 * (lo + hi);
        }
        w = w_new;
    }
    w
}

/// One-shot averaging on the Theorem-1 problem: draw m machines x n
/// samples, return (w_bar, w_hat) where w_bar is the average of
/// per-machine ERMs and w_hat is the ERM over all nm samples.
pub fn simulate_osa(lam: f64, n: usize, m: usize, seed: u64) -> (f64, f64) {
    let mut rng = Rng64::seed_from_u64(seed);
    let mut sum_w = 0.0;
    let mut total_z = 0.0;
    for _ in 0..m {
        let zsum: f64 = (0..n).map(|_| rng.normal()).sum();
        total_z += zsum;
        // target = z~ = zsum / sqrt(n)
        sum_w += solve_machine_erm(lam, n, zsum / (n as f64).sqrt());
    }
    let w_bar = sum_w / m as f64;
    let nm = n * m;
    let w_hat = solve_machine_erm(lam, nm, total_z / (nm as f64).sqrt());
    (w_bar, w_hat)
}

/// Population objective F(w) = E_z f(w; z) = lam (w^2/2 + e^w)
/// (the -zw term has zero mean).
pub fn population_f(lam: f64, w: f64) -> f64 {
    lam * (0.5 * w * w + w.exp())
}

/// Monte-Carlo estimate of E[(w_bar - w*)^2], E[(w_hat - w*)^2] and the
/// population suboptimality gaps, over `reps` replications.
pub struct Thm1Estimate {
    pub mse_osa: f64,
    pub mse_erm: f64,
    pub subopt_osa: f64,
    pub subopt_erm: f64,
}

pub fn estimate(lam: f64, n: usize, m: usize, reps: usize, seed: u64) -> Thm1Estimate {
    let mut e = Thm1Estimate { mse_osa: 0.0, mse_erm: 0.0, subopt_osa: 0.0, subopt_erm: 0.0 };
    let f_star = population_f(lam, W_STAR);
    for r in 0..reps {
        let (w_bar, w_hat) = simulate_osa(lam, n, m, seed.wrapping_add(r as u64));
        e.mse_osa += (w_bar - W_STAR).powi(2);
        e.mse_erm += (w_hat - W_STAR).powi(2);
        e.subopt_osa += population_f(lam, w_bar) - f_star;
        e.subopt_erm += population_f(lam, w_hat) - f_star;
    }
    let k = reps as f64;
    e.mse_osa /= k;
    e.mse_erm /= k;
    e.subopt_osa /= k;
    e.subopt_erm /= k;
    e
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn w_star_is_the_root() {
        assert!((W_STAR + W_STAR.exp()).abs() < 1e-12);
    }

    #[test]
    fn erm_solver_hits_target() {
        for &(lam, n, t) in &[(0.01, 100, 1.3), (0.05, 400, -2.0), (0.001, 50, 0.0)] {
            let w = solve_machine_erm(lam, n, t);
            let c = lam * (n as f64).sqrt();
            assert!((c * (w + w.exp()) - t).abs() < 1e-8, "lam={lam} n={n} t={t}");
        }
    }

    #[test]
    fn zero_target_gives_w_star() {
        let w = solve_machine_erm(0.01, 100, 0.0);
        assert!((w - W_STAR).abs() < 1e-10);
    }

    #[test]
    fn osa_bias_does_not_vanish_with_m() {
        // Theorem 1: for lam <= 1/(9 sqrt(n)) the OSA error is
        // Omega(1/(lam^2 n)) independent of m, while full ERM improves.
        let n = 100;
        let lam = 1.0 / (10.0 * (n as f64).sqrt());
        let e_small = estimate(lam, n, 4, 60, 42);
        let e_big = estimate(lam, n, 64, 60, 43);
        // ERM with 16x the data must be much better than OSA.
        assert!(
            e_big.mse_erm < e_big.mse_osa / 3.0,
            "erm {} vs osa {}",
            e_big.mse_erm,
            e_big.mse_osa
        );
        // OSA does not improve proportionally with m (bias floor):
        // allow anything better than 3x while ERM improved ~16x.
        assert!(
            e_big.mse_osa > e_small.mse_osa / 5.0,
            "osa m=64 {} vs m=4 {}",
            e_big.mse_osa,
            e_small.mse_osa
        );
    }

    #[test]
    fn population_f_minimized_at_w_star() {
        let f0 = population_f(0.02, W_STAR);
        for &dw in &[-0.1, -0.01, 0.01, 0.1] {
            assert!(population_f(0.02, W_STAR + dw) > f0);
        }
    }
}
