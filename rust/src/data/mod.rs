//! Datasets, shards and the generators substituting for the paper's data.
//!
//! The paper evaluates on COV1 (covertype), ASTRO-PH and MNIST-4v7 plus a
//! synthetic ridge problem. The real files are not redistributable in this
//! environment, so `synthetic.rs` builds generators matched on the
//! statistics the experiments actually exercise (dimensionality, sparsity,
//! separability, shard-to-shard Hessian concentration — see DESIGN.md §5).
//! `libsvm.rs` loads the real files when present, so the harness runs on
//! the original data unchanged if it is supplied.

pub mod libsvm;
pub mod sharding;
pub mod synthetic;
pub mod thm1;

pub use sharding::{shard_dataset, shard_indices};
pub use synthetic::{astro_like, covtype_like, mnist47_like, sparse_ridge, synthetic_fig2};

use crate::linalg::DataMatrix;

/// One worker's slice of the data.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Feature rows (possibly zero-padded at the bottom for the PJRT
    /// backend's fixed artifact shapes).
    pub x: DataMatrix,
    /// Targets (ridge) or labels in {-1, +1} (classification); exactly 0.0
    /// on padding rows.
    pub y: Vec<f64>,
    /// Number of *real* rows; objectives scale by 1/n_effective.
    n_effective: usize,
}

impl Shard {
    pub fn new(x: DataMatrix, y: Vec<f64>) -> Self {
        assert_eq!(x.rows(), y.len(), "shard x/y row mismatch");
        let n = x.rows();
        Shard { x, y, n_effective: n }
    }

    /// A shard whose trailing rows are padding (zero features, zero y).
    pub fn with_padding(x: DataMatrix, y: Vec<f64>, n_effective: usize) -> Self {
        assert_eq!(x.rows(), y.len(), "shard x/y row mismatch");
        assert!(n_effective <= x.rows(), "n_effective exceeds rows");
        Shard { x, y, n_effective }
    }

    /// Total rows including padding.
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    /// Real sample count (the `n` of the paper).
    pub fn n_effective(&self) -> usize {
        self.n_effective
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }
}

/// A full problem instance: train matrix + targets, optional test split,
/// and bookkeeping for the experiment harness.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: DataMatrix,
    pub y: Vec<f64>,
    pub test_x: Option<DataMatrix>,
    pub test_y: Option<Vec<f64>>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, x: DataMatrix, y: Vec<f64>) -> Self {
        assert_eq!(x.rows(), y.len(), "dataset x/y row mismatch");
        Dataset { name: name.into(), x, y, test_x: None, test_y: None }
    }

    pub fn with_test(mut self, x: DataMatrix, y: Vec<f64>) -> Self {
        assert_eq!(x.rows(), y.len(), "test x/y row mismatch");
        self.test_x = Some(x);
        self.test_y = Some(y);
        self
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// The whole training set as a single shard (reference ERM solves).
    pub fn as_single_shard(&self) -> Shard {
        Shard::new(self.x.clone(), self.y.clone())
    }

    /// The test split as a shard, if present.
    pub fn test_shard(&self) -> Option<Shard> {
        match (&self.test_x, &self.test_y) {
            (Some(x), Some(y)) => Some(Shard::new(x.clone(), y.clone())),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::DenseMatrix;

    #[test]
    fn shard_basics() {
        let x = DenseMatrix::zeros(4, 2);
        let s = Shard::new(DataMatrix::Dense(x), vec![1.0; 4]);
        assert_eq!(s.n(), 4);
        assert_eq!(s.n_effective(), 4);
        assert_eq!(s.d(), 2);
    }

    #[test]
    fn padded_shard_counts() {
        let x = DenseMatrix::zeros(8, 2);
        let s = Shard::with_padding(DataMatrix::Dense(x), vec![0.0; 8], 5);
        assert_eq!(s.n(), 8);
        assert_eq!(s.n_effective(), 5);
    }

    #[test]
    #[should_panic(expected = "x/y row mismatch")]
    fn shard_rejects_mismatch() {
        let x = DenseMatrix::zeros(4, 2);
        Shard::new(DataMatrix::Dense(x), vec![1.0; 3]);
    }

    #[test]
    fn dataset_single_shard() {
        let x = DenseMatrix::from_rows(&[vec![1.0], vec![2.0]]);
        let ds = Dataset::new("t", DataMatrix::Dense(x), vec![1.0, -1.0]);
        let s = ds.as_single_shard();
        assert_eq!(s.n(), 2);
        assert!(ds.test_shard().is_none());
    }
}
