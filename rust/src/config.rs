//! Typed experiment configuration, serialized as JSON through the
//! in-tree [`crate::util::json`] layer (the build is offline — no serde).
//!
//! The CLI launcher (`dane run --config exp.json`) and all example
//! binaries build runs from these structs; benches construct them in
//! code. Defaults reproduce the paper's settings.

use crate::comm::{ExecTopology, NetModel, Topology};
use crate::util::Json;
use crate::{Error, Result};
use std::path::Path;

/// Which loss to optimize.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossKind {
    Ridge,
    SmoothHinge,
    Logistic,
}

impl LossKind {
    pub fn name(&self) -> &'static str {
        match self {
            LossKind::Ridge => "ridge",
            LossKind::SmoothHinge => "smooth_hinge",
            LossKind::Logistic => "logistic",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "ridge" => Ok(LossKind::Ridge),
            "smooth_hinge" => Ok(LossKind::SmoothHinge),
            "logistic" => Ok(LossKind::Logistic),
            other => Err(Error::Config(format!("unknown loss {other:?}"))),
        }
    }
}

/// Which dataset to build.
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetConfig {
    /// Paper fig. 2 synthetic ridge model.
    Fig2 { n: usize, d: usize, paper_reg: f64 },
    /// COV1-like synthetic classification (d = 54 dense).
    CovtypeLike { n: usize, n_test: usize },
    /// ASTRO-PH-like synthetic sparse classification (d = 10_000).
    AstroLike { n: usize, n_test: usize },
    /// MNIST-4v7-like synthetic classification (d = 784 dense).
    Mnist47Like { n: usize, n_test: usize },
    /// Real data in LIBSVM format.
    Libsvm { path: String, dim: usize },
}

impl DatasetConfig {
    pub fn build(&self, seed: u64) -> Result<crate::data::Dataset> {
        Ok(match self {
            DatasetConfig::Fig2 { n, d, paper_reg } => {
                crate::data::synthetic_fig2(*n, *d, *paper_reg, seed)
            }
            DatasetConfig::CovtypeLike { n, n_test } => {
                crate::data::covtype_like(*n, *n_test, seed)
            }
            DatasetConfig::AstroLike { n, n_test } => {
                crate::data::astro_like(*n, *n_test, seed)
            }
            DatasetConfig::Mnist47Like { n, n_test } => {
                crate::data::mnist47_like(*n, *n_test, seed)
            }
            DatasetConfig::Libsvm { path, dim } => {
                crate::data::libsvm::load(Path::new(path), *dim)?
            }
        })
    }

    fn to_json(&self) -> Json {
        match self {
            DatasetConfig::Fig2 { n, d, paper_reg } => Json::obj(vec![
                ("kind", Json::str("fig2")),
                ("n", Json::num(*n as f64)),
                ("d", Json::num(*d as f64)),
                ("paper_reg", Json::num(*paper_reg)),
            ]),
            DatasetConfig::CovtypeLike { n, n_test } => Json::obj(vec![
                ("kind", Json::str("covtype_like")),
                ("n", Json::num(*n as f64)),
                ("n_test", Json::num(*n_test as f64)),
            ]),
            DatasetConfig::AstroLike { n, n_test } => Json::obj(vec![
                ("kind", Json::str("astro_like")),
                ("n", Json::num(*n as f64)),
                ("n_test", Json::num(*n_test as f64)),
            ]),
            DatasetConfig::Mnist47Like { n, n_test } => Json::obj(vec![
                ("kind", Json::str("mnist47_like")),
                ("n", Json::num(*n as f64)),
                ("n_test", Json::num(*n_test as f64)),
            ]),
            DatasetConfig::Libsvm { path, dim } => Json::obj(vec![
                ("kind", Json::str("libsvm")),
                ("path", Json::str(path.clone())),
                ("dim", Json::num(*dim as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or_default();
        let usz = |key: &str| -> Result<usize> {
            v.req(key)?
                .as_usize()
                .ok_or_else(|| Error::Config(format!("dataset.{key} must be a nonneg int")))
        };
        match kind {
            "fig2" => Ok(DatasetConfig::Fig2 {
                n: usz("n")?,
                d: usz("d")?,
                paper_reg: v.req("paper_reg")?.as_f64().unwrap_or(0.005),
            }),
            "covtype_like" => {
                Ok(DatasetConfig::CovtypeLike { n: usz("n")?, n_test: usz("n_test")? })
            }
            "astro_like" => {
                Ok(DatasetConfig::AstroLike { n: usz("n")?, n_test: usz("n_test")? })
            }
            "mnist47_like" => {
                Ok(DatasetConfig::Mnist47Like { n: usz("n")?, n_test: usz("n_test")? })
            }
            "libsvm" => Ok(DatasetConfig::Libsvm {
                path: v
                    .req("path")?
                    .as_str()
                    .ok_or_else(|| Error::Config("dataset.path must be a string".into()))?
                    .to_string(),
                dim: usz("dim")?,
            }),
            other => Err(Error::Config(format!("unknown dataset kind {other:?}"))),
        }
    }
}

/// Which algorithm to run, with its hyperparameters.
#[derive(Debug, Clone, PartialEq)]
pub enum AlgoConfig {
    /// The paper's method. `mu_over_lambda` expresses mu as a multiple of
    /// lambda (the paper sweeps mu in {0, lambda, 3 lambda}).
    Dane { eta: f64, mu_over_lambda: f64 },
    /// Distributed gradient descent (step = 1/L unless overridden).
    Gd { step: Option<f64> },
    /// Nesterov-accelerated distributed gradient descent.
    Agd { step: Option<f64> },
    /// Global-consensus ADMM (Boyd et al. 2011).
    Admm { rho: f64 },
    /// One-shot parameter averaging; `bias_correction_r` in (0,1) enables
    /// the Zhang et al. subsample correction.
    Osa { bias_correction_r: Option<f64> },
    /// Distributed L-BFGS with history size `history`.
    Lbfgs { history: usize },
}

impl AlgoConfig {
    pub fn name(&self) -> &'static str {
        match self {
            AlgoConfig::Dane { .. } => "dane",
            AlgoConfig::Gd { .. } => "gd",
            AlgoConfig::Agd { .. } => "agd",
            AlgoConfig::Admm { .. } => "admm",
            AlgoConfig::Osa { .. } => "osa",
            AlgoConfig::Lbfgs { .. } => "lbfgs",
        }
    }

    fn to_json(&self) -> Json {
        match self {
            AlgoConfig::Dane { eta, mu_over_lambda } => Json::obj(vec![
                ("kind", Json::str("dane")),
                ("eta", Json::num(*eta)),
                ("mu_over_lambda", Json::num(*mu_over_lambda)),
            ]),
            AlgoConfig::Gd { step } => Json::obj(vec![
                ("kind", Json::str("gd")),
                ("step", step.map(Json::num).unwrap_or(Json::Null)),
            ]),
            AlgoConfig::Agd { step } => Json::obj(vec![
                ("kind", Json::str("agd")),
                ("step", step.map(Json::num).unwrap_or(Json::Null)),
            ]),
            AlgoConfig::Admm { rho } => Json::obj(vec![
                ("kind", Json::str("admm")),
                ("rho", Json::num(*rho)),
            ]),
            AlgoConfig::Osa { bias_correction_r } => Json::obj(vec![
                ("kind", Json::str("osa")),
                (
                    "bias_correction_r",
                    bias_correction_r.map(Json::num).unwrap_or(Json::Null),
                ),
            ]),
            AlgoConfig::Lbfgs { history } => Json::obj(vec![
                ("kind", Json::str("lbfgs")),
                ("history", Json::num(*history as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let kind = v.req("kind")?.as_str().unwrap_or_default();
        let opt_f64 = |key: &str| v.get(key).and_then(|x| x.as_f64());
        match kind {
            "dane" => Ok(AlgoConfig::Dane {
                eta: opt_f64("eta").unwrap_or(1.0),
                mu_over_lambda: opt_f64("mu_over_lambda").unwrap_or(0.0),
            }),
            "gd" => Ok(AlgoConfig::Gd { step: opt_f64("step") }),
            "agd" => Ok(AlgoConfig::Agd { step: opt_f64("step") }),
            "admm" => Ok(AlgoConfig::Admm { rho: opt_f64("rho").unwrap_or(1.0) }),
            "osa" => Ok(AlgoConfig::Osa { bias_correction_r: opt_f64("bias_correction_r") }),
            "lbfgs" => Ok(AlgoConfig::Lbfgs {
                history: v
                    .get("history")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(10),
            }),
            other => Err(Error::Config(format!("unknown algo kind {other:?}"))),
        }
    }
}

/// Worker compute backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-rust local solves (any shape).
    #[default]
    Native,
    /// AOT HLO artifacts through PJRT (shapes padded to the manifest).
    Pjrt,
}

impl BackendKind {
    fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    fn from_name(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(Error::Config(format!("unknown backend {other:?}"))),
        }
    }
}

/// Which cluster engine drives the workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Inline single-threaded leader loop — deterministic, the
    /// measurement engine for figures and tests.
    #[default]
    Serial,
    /// One OS thread per worker behind the zero-allocation round
    /// protocol (`coordinator::threaded`). Bit-identical traces to
    /// `Serial` by construction (smoke_cluster_parity).
    Threaded,
    /// One OS *process* per worker speaking the `comm::wire` frame
    /// format over real sockets (`coordinator::tcp`). Workers come from
    /// the config's `workers` address list, or are spawned on loopback
    /// by the leader when the list is absent. Traces stay bit-identical
    /// to `Serial`; `wire_bytes` reports the measured socket traffic.
    Tcp,
}

impl EngineKind {
    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Serial => "serial",
            EngineKind::Threaded => "threaded",
            EngineKind::Tcp => "tcp",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "serial" => Ok(EngineKind::Serial),
            "threaded" => Ok(EngineKind::Threaded),
            "tcp" => Ok(EngineKind::Tcp),
            other => Err(Error::Config(format!(
                "unknown engine {other:?} (expected \"serial\", \"threaded\" or \"tcp\")"
            ))),
        }
    }

    /// Engine named by the environment variable `var` (the figure
    /// benches share `DANE_BENCH_ENGINE`); unset = serial, a set but
    /// invalid value is an error.
    pub fn from_env(var: &str) -> Result<Self> {
        match std::env::var(var) {
            Ok(v) => Self::from_name(&v),
            Err(std::env::VarError::NotPresent) => Ok(EngineKind::Serial),
            Err(std::env::VarError::NotUnicode(_)) => {
                Err(Error::Config(format!("{var} is not valid UTF-8")))
            }
        }
    }
}

/// What the run does when a worker dies or wedges mid-collective.
///
/// `FailFast` preserves the historical contract: the first lost worker
/// surfaces as an `AlgoError` and the run ends. `Respawn` restarts the
/// worker (re-spawning a self-hosted child or redialing an external
/// `dane worker --listen` address) with capped exponential backoff and
/// deterministic seeded jitter, then retries the failed collective.
/// `Degrade` quarantines the dead rank and continues on the surviving
/// quorum: the leader folds in rank order over the `alive` set with
/// 1/|alive| weighting, erroring out only when `alive < min_quorum`.
/// Fault-free runs are bit-identical under every policy.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum FaultPolicy {
    /// Any lost worker ends the run (the historical behavior).
    #[default]
    FailFast,
    /// Respawn/redial the lost worker and retry the round, up to
    /// `max_retries` recovery attempts per collective, sleeping
    /// `backoff_ms * 2^k` (+ seeded jitter, capped) between attempts.
    Respawn { max_retries: u32, backoff_ms: u64 },
    /// Drop the dead rank and continue on the survivors as long as at
    /// least `min_quorum` workers stay alive.
    Degrade { min_quorum: usize },
}

impl FaultPolicy {
    fn to_json(self) -> Json {
        match self {
            FaultPolicy::FailFast => {
                Json::obj(vec![("policy", Json::str("fail_fast"))])
            }
            FaultPolicy::Respawn { max_retries, backoff_ms } => Json::obj(vec![
                ("policy", Json::str("respawn")),
                ("max_retries", Json::num(max_retries as f64)),
                ("backoff_ms", Json::num(backoff_ms as f64)),
            ]),
            FaultPolicy::Degrade { min_quorum } => Json::obj(vec![
                ("policy", Json::str("degrade")),
                ("min_quorum", Json::num(min_quorum as f64)),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<Self> {
        let policy = v
            .req("policy")?
            .as_str()
            .ok_or_else(|| Error::Config("fault.policy must be a string".into()))?;
        match policy {
            "fail_fast" => Ok(FaultPolicy::FailFast),
            "respawn" => Ok(FaultPolicy::Respawn {
                max_retries: v
                    .get("max_retries")
                    .and_then(|x| x.as_u64())
                    .unwrap_or(3) as u32,
                backoff_ms: v
                    .get("backoff_ms")
                    .and_then(|x| x.as_u64())
                    .unwrap_or(100),
            }),
            "degrade" => Ok(FaultPolicy::Degrade {
                min_quorum: v
                    .get("min_quorum")
                    .and_then(|x| x.as_usize())
                    .unwrap_or(1),
            }),
            other => Err(Error::Config(format!(
                "unknown fault policy {other:?} (expected \"fail_fast\", \
                 \"respawn\" or \"degrade\")"
            ))),
        }
    }
}

/// Which wire codec compresses the O(d) round payloads (the
/// GradLoss/DaneSolve commands and their replies) on the concurrent
/// engines. See [`crate::comm::compress`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CompressionCodec {
    /// No compression — frames are bit-identical to the uncompressed
    /// protocol (the trust anchor for trace parity).
    #[default]
    None,
    /// Lossy f64 -> f32 downcast (2x).
    F32,
    /// Deterministic top-k magnitude sparsification: keep the k
    /// largest-|x| entries, ties broken toward the lower index.
    TopK { k: usize },
    /// Seeded stochastic quantization to `bits` bits per entry plus a
    /// sign bit, scaled by the vector's max-|x| norm.
    Quant { bits: u8 },
}

impl CompressionCodec {
    pub fn name(&self) -> &'static str {
        match self {
            CompressionCodec::None => "none",
            CompressionCodec::F32 => "f32",
            CompressionCodec::TopK { .. } => "topk",
            CompressionCodec::Quant { .. } => "quant",
        }
    }

    /// Parse the CLI spelling: `none`, `f32`, `topk:K` or `quant:B`.
    pub fn from_cli(s: &str) -> Result<Self> {
        match s {
            "none" => return Ok(CompressionCodec::None),
            "f32" => return Ok(CompressionCodec::F32),
            _ => {}
        }
        if let Some(k) = s.strip_prefix("topk:") {
            let k = k.parse::<usize>().map_err(|_| {
                Error::Config(format!("bad top-k count in --codec {s:?}"))
            })?;
            return Ok(CompressionCodec::TopK { k });
        }
        if let Some(b) = s.strip_prefix("quant:") {
            let bits = b.parse::<u8>().map_err(|_| {
                Error::Config(format!("bad bit width in --codec {s:?}"))
            })?;
            return Ok(CompressionCodec::Quant { bits });
        }
        Err(Error::Config(format!(
            "unknown codec {s:?} (expected \"none\", \"f32\", \"topk:K\" or \"quant:B\")"
        )))
    }
}

/// Round-payload compression settings. `error_feedback` keeps the
/// lossy codecs honest: each side accumulates what its codec dropped
/// and re-injects it next round, so compressed DANE/GD/AGD converge to
/// the same quality as the uncompressed run. Defaults to on; it is a
/// no-op under `codec: none` and `f32` is near-lossless either way.
/// JSON: `"compression": {"codec": "topk", "k": 100, "error_feedback":
/// true}` (the key is omitted entirely for the default, so uncompressed
/// configs serialize byte-identically to before this knob existed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompressionConfig {
    pub codec: CompressionCodec,
    pub error_feedback: bool,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig { codec: CompressionCodec::None, error_feedback: true }
    }
}

impl CompressionConfig {
    /// The wire codec to apply, `None` for the uncompressed protocol.
    pub fn codec(&self) -> Option<crate::comm::compress::Codec> {
        use crate::comm::compress::Codec;
        match self.codec {
            CompressionCodec::None => None,
            CompressionCodec::F32 => Some(Codec::F32),
            CompressionCodec::TopK { k } => Some(Codec::TopK { k }),
            CompressionCodec::Quant { bits } => Some(Codec::Quant { bits }),
        }
    }

    fn to_json(self) -> Json {
        let mut fields = vec![("codec", Json::str(self.codec.name()))];
        match self.codec {
            CompressionCodec::None | CompressionCodec::F32 => {}
            CompressionCodec::TopK { k } => {
                fields.push(("k", Json::num(k as f64)));
            }
            CompressionCodec::Quant { bits } => {
                fields.push(("bits", Json::num(bits as f64)));
            }
        }
        fields.push(("error_feedback", Json::Bool(self.error_feedback)));
        Json::obj(fields)
    }

    fn from_json(v: &Json) -> Result<Self> {
        let name = v
            .req("codec")?
            .as_str()
            .ok_or_else(|| Error::Config("compression.codec must be a string".into()))?;
        let k = match v.get("k") {
            None | Some(Json::Null) => None,
            Some(x) => Some(x.as_usize().ok_or_else(|| {
                Error::Config("compression.k must be a nonneg int".into())
            })?),
        };
        let bits = match v.get("bits") {
            None | Some(Json::Null) => None,
            Some(x) => Some(x.as_u64().ok_or_else(|| {
                Error::Config("compression.bits must be a nonneg int".into())
            })?),
        };
        let codec = match (name, k, bits) {
            ("none", None, None) => CompressionCodec::None,
            ("f32", None, None) => CompressionCodec::F32,
            ("topk", Some(k), None) => CompressionCodec::TopK { k },
            ("quant", None, Some(b)) => {
                if !(1..=8).contains(&b) {
                    return Err(Error::Config(
                        "compression.bits must be in 1..=8".into(),
                    ));
                }
                CompressionCodec::Quant { bits: b as u8 }
            }
            ("topk", None, _) => {
                return Err(Error::Config(
                    "compression.codec \"topk\" requires \"k\"".into(),
                ));
            }
            ("quant", _, None) => {
                return Err(Error::Config(
                    "compression.codec \"quant\" requires \"bits\"".into(),
                ));
            }
            ("none" | "f32" | "topk" | "quant", _, _) => {
                return Err(Error::Config(format!(
                    "compression key not valid for codec {name:?}"
                )));
            }
            (other, _, _) => {
                return Err(Error::Config(format!(
                    "unknown compression codec {other:?} (expected \"none\", \
                     \"f32\", \"topk\" or \"quant\")"
                )));
            }
        };
        let error_feedback = match v.get("error_feedback") {
            None | Some(Json::Null) => true,
            Some(b) => b.as_bool().ok_or_else(|| {
                Error::Config("compression.error_feedback must be a bool".into())
            })?,
        };
        Ok(CompressionConfig { codec, error_feedback })
    }
}

/// Serializable network-model config.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetConfig {
    pub alpha: f64,
    pub beta: f64,
    pub topology: Topology,
}

impl NetConfig {
    pub fn build(&self) -> NetModel {
        NetModel::new(self.alpha, self.beta, self.topology)
    }

    pub fn free() -> Self {
        NetConfig { alpha: 0.0, beta: 0.0, topology: Topology::Star }
    }

    pub fn datacenter() -> Self {
        let m = NetModel::datacenter();
        NetConfig { alpha: m.alpha, beta: m.beta, topology: m.topology }
    }

    fn topology_name(&self) -> &'static str {
        match self.topology {
            Topology::Star => "star",
            Topology::Ring => "ring",
            Topology::Tree => "tree",
        }
    }
}

/// A full experiment: dataset x algorithm x cluster shape.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetConfig,
    pub loss: LossKind,
    /// L2 regularization lambda. For Fig2 datasets prefer
    /// `data::synthetic::fig2_lambda(paper_reg)`.
    pub lambda: f64,
    pub algo: AlgoConfig,
    /// Number of machines m.
    pub machines: usize,
    /// Max communication-round iterations.
    pub rounds: usize,
    /// Stop when suboptimality falls below this (paper: 1e-6).
    pub tol: f64,
    pub seed: u64,
    pub backend: BackendKind,
    /// Which cluster engine runs the workers (default: serial).
    pub engine: EngineKind,
    /// TCP engine only: addresses of externally-launched `dane worker
    /// --listen` processes, one per machine. `None` means self-hosted —
    /// the leader spawns its own worker processes on loopback. Must be
    /// absent for in-memory engines.
    pub workers: Option<Vec<String>>,
    /// Override for the workers' Gram-build thread count (the
    /// deterministic `par_gram` kernel). Applies to *both* engines —
    /// it is a per-worker compute knob, orthogonal to the engine — so
    /// serial and threaded runs of the same config stay bit-identical.
    /// Only dense shards have a parallel Gram kernel; on sparse
    /// datasets (astro-like, libsvm) the override is a documented
    /// no-op. None = the built-in size ladder.
    pub threads: Option<usize>,
    /// Collective execution topology for the concurrent engines
    /// (`"star"` = parallel star, `"star-seq"` = the leader-serialized
    /// baseline, `"tree"` = binomial relay). When set, the network
    /// model's topology follows it ([`ExperimentConfig::effective_net`])
    /// so modeled and measured wallclock compare like with like; when
    /// absent (`None`) execution defaults to the parallel star and the
    /// `net.topology` key alone drives the model (legacy behavior).
    /// The serial engine executes inline either way — for it the key
    /// only selects the model, which is what makes a serial run's trace
    /// bit-comparable to a tree run's. Traces are bit-identical across
    /// topologies regardless; only `modeled_seconds`/`wire_bytes` move.
    pub topology: Option<ExecTopology>,
    /// TCP engine + libsvm dataset only: distribute shards **by
    /// reference**. Instead of streaming every shard row over the
    /// setup connections (O(n·d) startup bytes), the leader sends each
    /// worker one small `InitRef` frame naming the libsvm file and the
    /// sharding parameters, and the worker reads its own rows from
    /// local disk (O(m) startup bytes — see `startup_bytes` in the
    /// trace). Requires the file to be readable at the same path on
    /// every worker host; shard assignment and traces stay
    /// bit-identical to by-value distribution. JSON:
    /// `"data": {"by_ref": true}`.
    pub data_by_ref: bool,
    /// Evaluate test loss each round (fig. 4).
    pub eval_test: bool,
    /// What happens when a worker dies or wedges mid-run (default:
    /// fail fast, the historical behavior). JSON:
    /// `"fault": {"policy": "respawn", "max_retries": 3, "backoff_ms": 100}`
    /// or `{"policy": "degrade", "min_quorum": 2}`.
    pub fault: FaultPolicy,
    /// Round-payload wire compression (concurrent engines only;
    /// default: none). JSON: `"compression": {"codec": "topk", "k":
    /// 100, "error_feedback": true}`.
    pub compression: CompressionConfig,
    pub net: NetConfig,
}

impl ExperimentConfig {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            ("dataset", self.dataset.to_json()),
            ("loss", Json::str(self.loss.name())),
            ("lambda", Json::num(self.lambda)),
            ("algo", self.algo.to_json()),
            ("machines", Json::num(self.machines as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("tol", Json::num(self.tol)),
            ("seed", Json::num(self.seed as f64)),
            ("backend", Json::str(self.backend.name())),
            ("engine", Json::str(self.engine.name())),
            (
                "workers",
                self.workers
                    .as_ref()
                    .map(|ws| {
                        Json::Arr(ws.iter().map(|a| Json::str(a.clone())).collect())
                    })
                    .unwrap_or(Json::Null),
            ),
            (
                "threads",
                self.threads.map(|t| Json::num(t as f64)).unwrap_or(Json::Null),
            ),
            (
                "topology",
                self.topology.map(|t| Json::str(t.name())).unwrap_or(Json::Null),
            ),
            (
                "data",
                Json::obj(vec![("by_ref", Json::Bool(self.data_by_ref))]),
            ),
            ("eval_test", Json::Bool(self.eval_test)),
            ("fault", self.fault.to_json()),
        ];
        // The "compression" key is omitted for the default so existing
        // uncompressed configs serialize byte-identically to before the
        // knob existed.
        if self.compression != CompressionConfig::default() {
            fields.push(("compression", self.compression.to_json()));
        }
        fields.push((
            "net",
            Json::obj(vec![
                ("alpha", Json::num(self.net.alpha)),
                ("beta", Json::num(self.net.beta)),
                ("topology", Json::str(self.net.topology_name())),
            ]),
        ));
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let name = v.req("name")?.as_str().unwrap_or("unnamed").to_string();
        let dataset = DatasetConfig::from_json(v.req("dataset")?)?;
        let loss = LossKind::from_name(v.req("loss")?.as_str().unwrap_or_default())?;
        let lambda = v
            .req("lambda")?
            .as_f64()
            .ok_or_else(|| Error::Config("lambda must be a number".into()))?;
        let algo = AlgoConfig::from_json(v.req("algo")?)?;
        let machines = v
            .req("machines")?
            .as_usize()
            .ok_or_else(|| Error::Config("machines must be a nonneg int".into()))?;
        let rounds = v
            .req("rounds")?
            .as_usize()
            .ok_or_else(|| Error::Config("rounds must be a nonneg int".into()))?;
        let tol = v.get("tol").and_then(|x| x.as_f64()).unwrap_or(1e-6);
        let seed = v.get("seed").and_then(|x| x.as_u64()).unwrap_or(42);
        let backend = match v.get("backend").and_then(|x| x.as_str()) {
            Some(s) => BackendKind::from_name(s)?,
            None => BackendKind::Native,
        };
        let engine = match v.get("engine").and_then(|x| x.as_str()) {
            Some(s) => EngineKind::from_name(s)?,
            None => EngineKind::Serial,
        };
        let workers = match v.get("workers") {
            None | Some(Json::Null) => None,
            Some(w) => {
                let arr = w.as_array().ok_or_else(|| {
                    Error::Config("workers must be an array of addresses".into())
                })?;
                let mut addrs = Vec::with_capacity(arr.len());
                for a in arr {
                    addrs.push(
                        a.as_str()
                            .ok_or_else(|| {
                                Error::Config(
                                    "workers entries must be strings".into(),
                                )
                            })?
                            .to_string(),
                    );
                }
                Some(addrs)
            }
        };
        let threads = match v.get("threads") {
            None | Some(Json::Null) => None,
            Some(t) => Some(t.as_usize().ok_or_else(|| {
                Error::Config("threads must be a nonneg int".into())
            })?),
        };
        let topology = match v.get("topology") {
            None | Some(Json::Null) => None,
            Some(t) => Some(ExecTopology::from_name(t.as_str().ok_or_else(
                || Error::Config("topology must be a string".into()),
            )?)?),
        };
        let data_by_ref = match v.get("data") {
            None | Some(Json::Null) => false,
            Some(d) => match d.get("by_ref") {
                None | Some(Json::Null) => false,
                Some(b) => b.as_bool().ok_or_else(|| {
                    Error::Config("data.by_ref must be a bool".into())
                })?,
            },
        };
        let eval_test = v.get("eval_test").and_then(|x| x.as_bool()).unwrap_or(false);
        let fault = match v.get("fault") {
            None | Some(Json::Null) => FaultPolicy::FailFast,
            Some(f) => FaultPolicy::from_json(f)?,
        };
        let compression = match v.get("compression") {
            None | Some(Json::Null) => CompressionConfig::default(),
            Some(c) => CompressionConfig::from_json(c)?,
        };
        let net = match v.get("net") {
            Some(n) => {
                let topology = match n.get("topology").and_then(|x| x.as_str()) {
                    Some("ring") => Topology::Ring,
                    Some("tree") => Topology::Tree,
                    _ => Topology::Star,
                };
                NetConfig {
                    alpha: n.get("alpha").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    beta: n.get("beta").and_then(|x| x.as_f64()).unwrap_or(0.0),
                    topology,
                }
            }
            None => NetConfig::datacenter(),
        };
        Ok(ExperimentConfig {
            name,
            dataset,
            loss,
            lambda,
            algo,
            machines,
            rounds,
            tol,
            seed,
            backend,
            engine,
            workers,
            threads,
            topology,
            data_by_ref,
            eval_test,
            fault,
            compression,
            net,
        })
    }

    /// The collective execution topology the concurrent engines run
    /// (default: parallel star).
    pub fn exec_topology(&self) -> ExecTopology {
        self.topology.unwrap_or_default()
    }

    /// The network model the run is accounted under. An explicit
    /// `topology` key overrides the model's topology to match the
    /// execution strategy, so `modeled_seconds` and measured wallclock
    /// describe the same collective algorithm; without it the
    /// `net.topology` key stands alone (legacy configs keep their
    /// numbers).
    pub fn effective_net(&self) -> NetModel {
        match self.topology {
            Some(t) => NetModel::new(self.net.alpha, self.net.beta, t.net_topology()),
            None => self.net.build(),
        }
    }

    pub fn from_json_str(s: &str) -> Result<Self> {
        Self::from_json(&Json::parse(s)?)
    }

    pub fn from_json_file(path: &Path) -> Result<Self> {
        Self::from_json_str(&std::fs::read_to_string(path)?)
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string_pretty()
    }

    /// Sanity-check the combination.
    pub fn validate(&self) -> Result<()> {
        if self.machines == 0 {
            return Err(Error::Config("machines must be >= 1".into()));
        }
        if self.rounds == 0 {
            return Err(Error::Config("rounds must be >= 1".into()));
        }
        if self.lambda < 0.0 {
            return Err(Error::Config("lambda must be >= 0".into()));
        }
        if self.threads == Some(0) {
            return Err(Error::Config("threads must be >= 1".into()));
        }
        if self.engine != EngineKind::Serial && self.backend == BackendKind::Pjrt {
            return Err(Error::Config(
                "pjrt backend requires the serial engine".into(),
            ));
        }
        match (&self.workers, self.engine) {
            (Some(_), EngineKind::Serial | EngineKind::Threaded) => {
                return Err(Error::Config(
                    "workers addresses require engine \"tcp\"".into(),
                ));
            }
            (Some(ws), EngineKind::Tcp) => {
                if ws.is_empty() {
                    return Err(Error::Config(
                        "workers must list >= 1 address".into(),
                    ));
                }
                if ws.len() != self.machines {
                    return Err(Error::Config(format!(
                        "workers lists {} addresses but machines = {}",
                        ws.len(),
                        self.machines
                    )));
                }
            }
            (None, _) => {}
        }
        if self.data_by_ref {
            if self.engine != EngineKind::Tcp {
                return Err(Error::Config(
                    "data.by_ref requires engine \"tcp\" (in-memory engines share \
                     the leader's address space — there is no wire to save)"
                        .into(),
                ));
            }
            if !matches!(self.dataset, DatasetConfig::Libsvm { .. }) {
                return Err(Error::Config(
                    "data.by_ref requires a libsvm dataset (workers re-read their \
                     shard rows from the file; synthetic datasets have no file)"
                        .into(),
                ));
            }
        }
        if matches!(self.loss, LossKind::Ridge)
            && matches!(
                self.dataset,
                DatasetConfig::CovtypeLike { .. }
                    | DatasetConfig::AstroLike { .. }
                    | DatasetConfig::Mnist47Like { .. }
            )
        {
            return Err(Error::Config(
                "classification datasets need a classification loss".into(),
            ));
        }
        match self.fault {
            FaultPolicy::FailFast => {}
            FaultPolicy::Respawn { max_retries, .. } => {
                if max_retries == 0 {
                    return Err(Error::Config(
                        "fault.max_retries must be >= 1 (0 retries is fail_fast)"
                            .into(),
                    ));
                }
            }
            FaultPolicy::Degrade { min_quorum } => {
                if min_quorum == 0 || min_quorum > self.machines {
                    return Err(Error::Config(format!(
                        "fault.min_quorum must be in 1..={} (machines)",
                        self.machines
                    )));
                }
            }
        }
        match self.compression.codec {
            CompressionCodec::None => {}
            CompressionCodec::F32 => {}
            CompressionCodec::TopK { k } => {
                if k == 0 {
                    return Err(Error::Config(
                        "compression.k must be >= 1".into(),
                    ));
                }
            }
            CompressionCodec::Quant { bits } => {
                if !(1..=8).contains(&bits) {
                    return Err(Error::Config(
                        "compression.bits must be in 1..=8".into(),
                    ));
                }
            }
        }
        if self.compression.codec != CompressionCodec::None
            && self.engine == EngineKind::Serial
        {
            return Err(Error::Config(
                "compression requires a concurrent engine (\"threaded\" or \
                 \"tcp\") — the serial engine has no wire to shrink"
                    .into(),
            ));
        }
        if let AlgoConfig::Osa { bias_correction_r: Some(r) } = self.algo {
            if !(0.0 < r && r < 1.0) {
                return Err(Error::Config(
                    "bias_correction_r must be in (0,1)".into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ExperimentConfig {
        ExperimentConfig {
            name: "t".into(),
            dataset: DatasetConfig::Fig2 { n: 1000, d: 50, paper_reg: 0.005 },
            loss: LossKind::Ridge,
            lambda: 0.01,
            algo: AlgoConfig::Dane { eta: 1.0, mu_over_lambda: 0.0 },
            machines: 4,
            rounds: 20,
            tol: 1e-6,
            seed: 42,
            backend: BackendKind::Native,
            engine: EngineKind::Serial,
            workers: None,
            threads: None,
            topology: None,
            data_by_ref: false,
            eval_test: false,
            fault: FaultPolicy::FailFast,
            compression: CompressionConfig::default(),
            net: NetConfig::free(),
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = sample();
        let s = c.to_json_string();
        let c2 = ExperimentConfig::from_json_str(&s).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn parses_handwritten_json() {
        let s = r#"{
            "name": "fig3-cov1",
            "loss": "smooth_hinge",
            "lambda": 1e-5,
            "machines": 16,
            "rounds": 100,
            "dataset": {"kind": "covtype_like", "n": 10000, "n_test": 1000},
            "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 3.0}
        }"#;
        let c = ExperimentConfig::from_json_str(s).unwrap();
        assert_eq!(c.machines, 16);
        assert_eq!(c.tol, 1e-6); // default
        assert_eq!(c.algo.name(), "dane");
        assert_eq!(c.net, NetConfig::datacenter()); // default
        assert_eq!(c.engine, EngineKind::Serial); // default
        assert_eq!(c.threads, None); // default
        c.validate().unwrap();
    }

    #[test]
    fn topology_roundtrips_and_drives_the_net_model() {
        for topo in [
            None,
            Some(ExecTopology::StarSeq),
            Some(ExecTopology::Star),
            Some(ExecTopology::Tree),
        ] {
            let mut c = sample();
            c.engine = EngineKind::Threaded;
            c.topology = topo;
            c.net = NetConfig::datacenter(); // net.topology = Ring
            let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
            assert_eq!(c2.topology, topo);
            c2.validate().unwrap();
            // an explicit topology key overrides the model's topology;
            // absent, the net config stands alone (legacy behavior)
            let expect = match topo {
                None => Topology::Ring,
                Some(t) => t.net_topology(),
            };
            assert_eq!(c2.effective_net().topology, expect);
            assert_eq!(c2.effective_net().alpha, c2.net.alpha);
            assert_eq!(c2.exec_topology(), topo.unwrap_or(ExecTopology::Star));
        }
        // handwritten key + bad value
        let s = sample()
            .to_json_string()
            .replacen("\"topology\": null", "\"topology\": \"tree\"", 1);
        let c = ExperimentConfig::from_json_str(&s).unwrap();
        assert_eq!(c.topology, Some(ExecTopology::Tree));
        let s = sample()
            .to_json_string()
            .replacen("\"topology\": null", "\"topology\": \"ring\"", 1);
        assert!(ExperimentConfig::from_json_str(&s).is_err());
        let s = sample()
            .to_json_string()
            .replacen("\"topology\": null", "\"topology\": 3", 1);
        assert!(ExperimentConfig::from_json_str(&s).is_err());
    }

    #[test]
    fn engine_and_threads_roundtrip() {
        for (engine, threads) in [
            (EngineKind::Serial, None),
            (EngineKind::Serial, Some(4)),
            (EngineKind::Threaded, None),
            (EngineKind::Threaded, Some(2)),
        ] {
            let mut c = sample();
            c.engine = engine;
            c.threads = threads;
            let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
            assert_eq!(c2.engine, engine);
            assert_eq!(c2.threads, threads);
            c2.validate().unwrap();
        }
    }

    #[test]
    fn tcp_engine_and_workers_roundtrip() {
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.workers = Some(vec!["127.0.0.1:7001".into(), "127.0.0.1:7002".into()]);
        c.machines = 2;
        let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
        assert_eq!(c2.engine, EngineKind::Tcp);
        assert_eq!(c2.workers, c.workers);
        c2.validate().unwrap();

        // self-hosted: tcp with no workers list is valid
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
        assert_eq!(c2.workers, None);
        c2.validate().unwrap();
    }

    #[test]
    fn data_by_ref_roundtrips_and_is_gated() {
        // roundtrip with the flag on (tcp + libsvm is the valid combo)
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.dataset = DatasetConfig::Libsvm { path: "/data/f.svm".into(), dim: 10 };
        c.data_by_ref = true;
        let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
        assert!(c2.data_by_ref);
        c2.validate().unwrap();

        // absent "data" key defaults to by-value
        let s = r#"{
            "name": "t", "loss": "ridge", "lambda": 0.01,
            "machines": 2, "rounds": 5,
            "dataset": {"kind": "fig2", "n": 100, "d": 5, "paper_reg": 0.005},
            "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0}
        }"#;
        assert!(!ExperimentConfig::from_json_str(s).unwrap().data_by_ref);

        // by_ref needs the tcp engine
        let mut c = sample();
        c.dataset = DatasetConfig::Libsvm { path: "/data/f.svm".into(), dim: 10 };
        c.data_by_ref = true;
        assert!(c.validate().is_err(), "by_ref off-tcp must be rejected");

        // ... and a libsvm dataset (synthetic data has no file)
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.data_by_ref = true;
        assert!(c.validate().is_err(), "by_ref without a file must be rejected");

        // malformed flag type
        let s = sample()
            .to_json_string()
            .replacen("\"by_ref\": false", "\"by_ref\": 1", 1);
        assert!(ExperimentConfig::from_json_str(&s).is_err());
    }

    #[test]
    fn workers_validation_catches_mismatches() {
        // workers without the tcp engine
        let mut c = sample();
        c.workers = Some(vec!["127.0.0.1:7001".into(); 4]);
        assert!(c.validate().is_err(), "workers need engine tcp");

        // count mismatch with machines
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.workers = Some(vec!["127.0.0.1:7001".into()]);
        c.machines = 4;
        assert!(c.validate().is_err(), "workers/machines mismatch");

        // empty list
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.workers = Some(Vec::new());
        assert!(c.validate().is_err(), "empty workers list");

        // tcp + pjrt is rejected like threaded + pjrt
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.backend = BackendKind::Pjrt;
        assert!(c.validate().is_err(), "pjrt is serial-engine only");

        // malformed workers JSON
        let s = sample()
            .to_json_string()
            .replacen("\"workers\": null", "\"workers\": [1, 2]", 1);
        assert!(ExperimentConfig::from_json_str(&s).is_err());
    }

    #[test]
    fn engine_parses_from_handwritten_json() {
        let mut base = sample().to_json_string();
        base = base.replacen("\"engine\": \"serial\"", "\"engine\": \"threaded\"", 1);
        let c = ExperimentConfig::from_json_str(&base).unwrap();
        assert_eq!(c.engine, EngineKind::Threaded);
        assert!(EngineKind::from_name("bogus").is_err());
    }

    #[test]
    fn engine_validation_catches_mismatches() {
        let mut c = sample();
        c.threads = Some(0);
        assert!(c.validate().is_err(), "threads: 0 must be rejected");

        let mut c = sample();
        c.engine = EngineKind::Threaded;
        c.backend = BackendKind::Pjrt;
        assert!(c.validate().is_err(), "pjrt is serial-engine only");

        let mut c = sample();
        c.engine = EngineKind::Threaded;
        c.threads = Some(2);
        c.validate().unwrap();
    }

    #[test]
    fn fault_policy_roundtrips_and_validates() {
        for fault in [
            FaultPolicy::FailFast,
            FaultPolicy::Respawn { max_retries: 5, backoff_ms: 50 },
            FaultPolicy::Degrade { min_quorum: 2 },
        ] {
            let mut c = sample();
            c.fault = fault;
            let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
            assert_eq!(c2.fault, fault);
            c2.validate().unwrap();
        }

        // absent key defaults to fail_fast
        let s = r#"{
            "name": "t", "loss": "ridge", "lambda": 0.01,
            "machines": 2, "rounds": 5,
            "dataset": {"kind": "fig2", "n": 100, "d": 5, "paper_reg": 0.005},
            "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0}
        }"#;
        let c = ExperimentConfig::from_json_str(s).unwrap();
        assert_eq!(c.fault, FaultPolicy::FailFast);

        // handwritten policy with defaults filled in
        let s = r#"{
            "name": "t", "loss": "ridge", "lambda": 0.01,
            "machines": 2, "rounds": 5,
            "dataset": {"kind": "fig2", "n": 100, "d": 5, "paper_reg": 0.005},
            "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
            "fault": {"policy": "respawn"}
        }"#;
        let c = ExperimentConfig::from_json_str(s).unwrap();
        assert_eq!(
            c.fault,
            FaultPolicy::Respawn { max_retries: 3, backoff_ms: 100 }
        );

        // unknown policy is a parse error
        let s = sample()
            .to_json_string()
            .replacen("\"fail_fast\"", "\"bogus\"", 1);
        assert!(ExperimentConfig::from_json_str(&s).is_err());

        // validation gates
        let mut c = sample();
        c.fault = FaultPolicy::Respawn { max_retries: 0, backoff_ms: 10 };
        assert!(c.validate().is_err(), "0 retries must be rejected");
        let mut c = sample();
        c.fault = FaultPolicy::Degrade { min_quorum: 0 };
        assert!(c.validate().is_err(), "quorum 0 must be rejected");
        let mut c = sample();
        c.fault = FaultPolicy::Degrade { min_quorum: 5 };
        assert!(c.validate().is_err(), "quorum > machines must be rejected");
    }

    #[test]
    fn compression_roundtrips_and_validates() {
        for codec in [
            CompressionCodec::F32,
            CompressionCodec::TopK { k: 10 },
            CompressionCodec::Quant { bits: 4 },
        ] {
            for ef in [true, false] {
                let mut c = sample();
                c.engine = EngineKind::Threaded;
                c.compression = CompressionConfig { codec, error_feedback: ef };
                let c2 =
                    ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
                assert_eq!(c2.compression, c.compression);
                c2.validate().unwrap();
            }
        }

        // the default serializes with no "compression" key at all, so
        // uncompressed configs are byte-identical to the pre-knob format
        let s = sample().to_json_string();
        assert!(!s.contains("compression"), "default must omit the key:\n{s}");
        let c = ExperimentConfig::from_json_str(&s).unwrap();
        assert_eq!(c.compression, CompressionConfig::default());

        // validation gates
        let mut c = sample();
        c.compression =
            CompressionConfig { codec: CompressionCodec::F32, error_feedback: true };
        assert!(c.validate().is_err(), "serial engine has no wire to compress");
        let mut c = sample();
        c.engine = EngineKind::Threaded;
        c.compression = CompressionConfig {
            codec: CompressionCodec::TopK { k: 0 },
            error_feedback: true,
        };
        assert!(c.validate().is_err(), "k = 0 must be rejected");
        let mut c = sample();
        c.engine = EngineKind::Tcp;
        c.compression = CompressionConfig {
            codec: CompressionCodec::Quant { bits: 9 },
            error_feedback: true,
        };
        assert!(c.validate().is_err(), "bits > 8 must be rejected");

        // handwritten JSON: missing/stray params and bad kinds error
        let base = r#"{
            "name": "t", "loss": "ridge", "lambda": 0.01,
            "machines": 2, "rounds": 5, "engine": "threaded",
            "dataset": {"kind": "fig2", "n": 100, "d": 5, "paper_reg": 0.005},
            "algo": {"kind": "dane", "eta": 1.0, "mu_over_lambda": 0.0},
            "compression": COMP
        }"#;
        let parse = |comp: &str| {
            ExperimentConfig::from_json_str(&base.replacen("COMP", comp, 1))
        };
        let c = parse(r#"{"codec": "topk", "k": 7}"#).unwrap();
        assert_eq!(
            c.compression,
            CompressionConfig {
                codec: CompressionCodec::TopK { k: 7 },
                error_feedback: true, // defaults on
            }
        );
        assert!(parse(r#"{"codec": "topk"}"#).is_err(), "topk needs k");
        assert!(parse(r#"{"codec": "quant"}"#).is_err(), "quant needs bits");
        assert!(parse(r#"{"codec": "quant", "bits": 0}"#).is_err());
        assert!(parse(r#"{"codec": "f32", "k": 3}"#).is_err(), "stray k");
        assert!(parse(r#"{"codec": "none", "bits": 2}"#).is_err(), "stray bits");
        assert!(parse(r#"{"codec": "middleout"}"#).is_err(), "unknown codec");
        assert!(parse(r#"{"codec": "f32", "error_feedback": 1}"#).is_err());

        // CLI spellings
        assert_eq!(
            CompressionCodec::from_cli("topk:100").unwrap(),
            CompressionCodec::TopK { k: 100 }
        );
        assert_eq!(
            CompressionCodec::from_cli("quant:4").unwrap(),
            CompressionCodec::Quant { bits: 4 }
        );
        assert_eq!(CompressionCodec::from_cli("f32").unwrap(), CompressionCodec::F32);
        assert_eq!(
            CompressionCodec::from_cli("none").unwrap(),
            CompressionCodec::None
        );
        assert!(CompressionCodec::from_cli("topk").is_err());
        assert!(CompressionCodec::from_cli("topk:x").is_err());
        assert!(CompressionCodec::from_cli("gzip").is_err());

        // codec() maps onto the wire-layer codec enum
        use crate::comm::compress::Codec;
        let cc = CompressionConfig {
            codec: CompressionCodec::TopK { k: 5 },
            error_feedback: false,
        };
        assert_eq!(cc.codec(), Some(Codec::TopK { k: 5 }));
        assert_eq!(CompressionConfig::default().codec(), None);
    }

    #[test]
    fn every_algo_roundtrips() {
        for algo in [
            AlgoConfig::Dane { eta: 0.9, mu_over_lambda: 3.0 },
            AlgoConfig::Gd { step: Some(0.1) },
            AlgoConfig::Gd { step: None },
            AlgoConfig::Agd { step: None },
            AlgoConfig::Admm { rho: 0.7 },
            AlgoConfig::Osa { bias_correction_r: Some(0.5) },
            AlgoConfig::Osa { bias_correction_r: None },
            AlgoConfig::Lbfgs { history: 7 },
        ] {
            let mut c = sample();
            c.algo = algo.clone();
            let c2 = ExperimentConfig::from_json_str(&c.to_json_string()).unwrap();
            assert_eq!(c2.algo, algo);
        }
    }

    #[test]
    fn validation_catches_mismatches() {
        let mut c = sample();
        c.machines = 0;
        assert!(c.validate().is_err());

        let mut c = sample();
        c.dataset = DatasetConfig::CovtypeLike { n: 100, n_test: 10 };
        assert!(c.validate().is_err()); // ridge on classification data

        let mut c = sample();
        c.algo = AlgoConfig::Osa { bias_correction_r: Some(1.5) };
        assert!(c.validate().is_err());
    }

    #[test]
    fn dataset_build_dispatch() {
        let ds = DatasetConfig::Fig2 { n: 50, d: 5, paper_reg: 0.005 }
            .build(1)
            .unwrap();
        assert_eq!(ds.n(), 50);
        assert!(DatasetConfig::Libsvm { path: "/nonexistent".into(), dim: 0 }
            .build(1)
            .is_err());
    }
}
