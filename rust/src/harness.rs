//! Paper-experiment harnesses (DESIGN.md §4).
//!
//! One function per table/figure, shared by the CLI (`dane fig2`...), the
//! criterion benches and the examples. Each harness builds the workloads,
//! runs every algorithm the paper compares, writes per-run CSV traces and
//! returns (and prints) the figure's rows/series. `scale` divides sample
//! sizes so the same code smoke-tests in seconds and reproduces at full
//! size; EXPERIMENTS.md records the scale used for the committed numbers.

use crate::comm::{ExecTopology, NetModel};
use crate::config::{EngineKind, LossKind};
use crate::coordinator::tcp::TcpCluster;
use crate::coordinator::threaded::ThreadedCluster;
use crate::coordinator::{admm, dane, osa, Cluster, RunCtx, SerialCluster};
use crate::data::{self, Dataset};
use crate::loss::{make_objective, Objective};
use crate::metrics::emit;
use crate::metrics::Trace;
use crate::solver::erm_solve;
use crate::Result;
use std::path::Path;
use std::sync::Arc;

/// Construct the requested cluster engine — the single point where the
/// harnesses (and through them the CLI figure subcommands and benches)
/// pick serial vs threaded vs tcp. Same shards, same reduction order:
/// the figure numbers are engine-independent bit for bit. The tcp
/// engine self-hosts worker processes on loopback (it needs the loss by
/// name to ship in the Init frames, hence the `loss`/`lambda` pair
/// instead of a prebuilt objective); it can fail to come up, hence the
/// `Result`.
#[allow(clippy::too_many_arguments)]
fn build_cluster(
    ds: &Dataset,
    loss: LossKind,
    lambda: f64,
    m: usize,
    seed: u64,
    net: NetModel,
    engine: EngineKind,
    topology: ExecTopology,
) -> Result<Box<dyn Cluster>> {
    let obj = make_objective(loss, lambda);
    Ok(match engine {
        // inline execution — the topology only matters to the model,
        // which the caller already picked via `net`
        EngineKind::Serial => Box::new(SerialCluster::with_net(ds, obj, m, seed, net)),
        EngineKind::Threaded => Box::new(ThreadedCluster::with_topology(
            ds, obj, m, seed, net, None, topology,
        )),
        EngineKind::Tcp => Box::new(TcpCluster::self_hosted(
            ds, loss, lambda, m, seed, net, None, None, topology,
        )?),
    })
}

// ---------------------------------------------------------------------
// quickstart
// ---------------------------------------------------------------------

/// Tiny end-to-end smoke run: fig. 2 setup, m = 4, a few rounds, on the
/// requested engine and collective topology.
pub fn quickstart(engine: EngineKind, topology: ExecTopology) -> Result<()> {
    let ds = data::synthetic_fig2(2048, 100, 0.005, 42);
    let lam = data::synthetic::fig2_lambda(0.005);
    let obj = make_objective(crate::config::LossKind::Ridge, lam);
    let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
    let mut cluster = build_cluster(
        &ds,
        crate::config::LossKind::Ridge,
        lam,
        4,
        42,
        NetModel::free(),
        engine,
        topology,
    )?;
    let ctx = RunCtx::new(15).with_reference(phi_star).with_tol(1e-10);
    let res = dane::run(cluster.as_mut(), &dane::DaneOptions::default(), &ctx)?;
    println!(
        "quickstart: DANE on fig2(n=2048, d=100), m=4 [engine: {} topology: {}]",
        engine.name(),
        topology.name()
    );
    for r in &res.trace.rows {
        println!(
            "  round {:>2}  subopt {:>10.3e}  comm_rounds {}",
            r.round,
            r.suboptimality.unwrap_or(f64::NAN),
            r.comm_rounds
        );
    }
    println!("converged: {}", res.converged);
    Ok(())
}

/// Sparse high-dimensional smoke run (`dane quickstart --sparse`):
/// ridge on a d = 50_000 sparse instance, m = 4, a few DANE rounds.
/// Every local solve is matrix-free Newton-CG — a dense d x d Gram
/// here would be 20 GB, so this run doubles as the CI memory canary
/// (scale-smoke runs it under `ulimit -v`). No reference ERM (the
/// suboptimality axis needs a full-precision solve; the smoke prints
/// objective and gradient norm instead).
pub fn quickstart_sparse(engine: EngineKind, topology: ExecTopology) -> Result<()> {
    let (n, d, nnz) = (4096, 50_000, 3);
    let ds = data::sparse_ridge(n, d, nnz, 42);
    let lam = 1e-3;
    let mut cluster = build_cluster(
        &ds,
        crate::config::LossKind::Ridge,
        lam,
        4,
        42,
        NetModel::free(),
        engine,
        topology,
    )?;
    let ctx = RunCtx::new(6).with_tol(0.0);
    let res = dane::run(cluster.as_mut(), &dane::DaneOptions::default(), &ctx)?;
    println!(
        "quickstart-sparse: DANE on sparse-ridge(n={n}, d={d}, {nnz} nnz/row), m=4 \
         [engine: {} topology: {}]",
        engine.name(),
        topology.name()
    );
    for r in &res.trace.rows {
        println!(
            "  round {:>2}  objective {:>12.6e}  gradnorm {:>10}  comm_rounds {}",
            r.round,
            r.objective,
            r.grad_norm.map(|g| format!("{g:.3e}")).unwrap_or_default(),
            r.comm_rounds
        );
    }
    println!("final objective: {:.6e}", res.trace.last_objective().unwrap_or(f64::NAN));
    Ok(())
}

// ---------------------------------------------------------------------
// fig. 2 — synthetic ridge: DANE vs ADMM across m x N
// ---------------------------------------------------------------------

/// One (algorithm, m, N) cell of the fig. 2 grid.
#[derive(Debug, Clone)]
pub struct Fig2Cell {
    pub algo: &'static str,
    pub m: usize,
    pub n_total: usize,
    /// log10 suboptimality per iteration (the figure's y-axis).
    pub log10_subopt: Vec<f64>,
    /// Mean per-iteration contraction factor (rate diagnostics).
    pub mean_contraction: f64,
}

/// The paper's grid: m in {4, 16, 64}, N in {4096, 16384, 65536}/scale,
/// d = 500, ridge reg 0.005, DANE(eta=1, mu=0) vs ADMM.
pub fn fig2(
    scale: usize,
    out: &Path,
    engine: EngineKind,
    topology: ExecTopology,
) -> Result<Vec<Fig2Cell>> {
    let d = 500;
    let paper_reg = 0.005;
    let lam = data::synthetic::fig2_lambda(paper_reg);
    let ms = [4usize, 16, 64];
    let ns: Vec<usize> = [4096usize, 16384, 65536]
        .iter()
        .map(|n| (n / scale).max(256))
        .collect();
    let rounds = 30;
    std::fs::create_dir_all(out)?;

    let mut cells = Vec::new();
    for &n_total in &ns {
        let ds = data::synthetic_fig2(n_total, d, paper_reg, 42);
        let obj = make_objective(crate::config::LossKind::Ridge, lam);
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
        for &m in &ms {
            if n_total / m < 2 {
                continue;
            }
            for algo in ["dane", "admm"] {
                let mut cluster = build_cluster(
                    &ds,
                    crate::config::LossKind::Ridge,
                    lam,
                    m,
                    7,
                    NetModel::datacenter(),
                    engine,
                    topology,
                )?;
                let ctx = RunCtx::new(rounds)
                    .with_reference(phi_star)
                    .with_tol(1e-13);
                let res = match algo {
                    "dane" => dane::run(cluster.as_mut(), &dane::DaneOptions::default(), &ctx)?,
                    _ => admm::run(
                        cluster.as_mut(),
                        &admm::AdmmOptions { rho: lam.max(0.05) },
                        &ctx,
                    )?,
                };
                let cell = summarize_fig2(algo, m, n_total, &res.trace);
                emit::write_csv_file(
                    &res.trace,
                    &out.join(format!("{algo}_m{m}_N{n_total}.csv")),
                )?;
                println!(
                    "fig2 {algo:>4} m={m:<3} N={n_total:<6} mean contraction {:.3}  final log10 subopt {:.2}",
                    cell.mean_contraction,
                    cell.log10_subopt.last().copied().unwrap_or(f64::NAN),
                );
                cells.push(cell);
            }
        }
    }
    Ok(cells)
}

fn summarize_fig2(algo: &'static str, m: usize, n_total: usize, trace: &Trace) -> Fig2Cell {
    let log10: Vec<f64> = trace
        .suboptimality()
        .iter()
        .map(|s| s.max(1e-300).log10())
        .collect();
    let f = trace.contraction_factors();
    let k = f.len().min(8).max(1);
    let mean = if f.is_empty() {
        f64::NAN
    } else {
        f.iter().take(k).sum::<f64>() / k as f64
    };
    Fig2Cell { algo, m, n_total, log10_subopt: log10, mean_contraction: mean }
}

// ---------------------------------------------------------------------
// fig. 3 — iterations to < 1e-6 on three datasets
// ---------------------------------------------------------------------

/// Consensus-ADMM penalty for the fig. 3/4 hinge workloads (coarse-tuned;
/// see fig3 docs — rho drives ADMM's rate, lambda does not).
pub const ADMM_RHO: f64 = 0.1;

/// One dataset column of the fig. 3 table.
#[derive(Debug, Clone)]
pub struct Fig3Column {
    pub dataset: String,
    pub ms: Vec<usize>,
    /// rows: (label, iterations per m; None = no convergence in budget)
    pub rows: Vec<(String, Vec<Option<usize>>)>,
}

/// Build the three fig-3/fig-4 datasets at `scale`.
pub fn fig34_datasets(scale: usize) -> Vec<(Dataset, f64)> {
    // (dataset, lambda): lambdas follow the paper's footnote 6.
    vec![
        (data::covtype_like((20_000 / scale).max(1024), 2048, 11), 1e-5),
        (data::astro_like((20_000 / scale).max(1024), 2048, 12), 5e-4),
        (data::mnist47_like((8_000 / scale).max(1024), 2048, 13), 1e-3),
    ]
}

/// The fig. 3 table: smooth hinge on cov1-like / astro-like / mnist47-like,
/// m in {2..64}, DANE (mu = 0 and mu = 3 lambda) and ADMM; entry =
/// iterations to suboptimality < 1e-6 (None = "*", no convergence within
/// the budget, exactly the paper's notation).
pub fn fig3(
    scale: usize,
    out: &Path,
    engine: EngineKind,
    topology: ExecTopology,
) -> Result<Vec<Fig3Column>> {
    let ms = vec![2usize, 4, 8, 16, 32, 64];
    let budget = 100;
    std::fs::create_dir_all(out)?;
    let mut columns = Vec::new();

    for (ds, lam) in fig34_datasets(scale) {
        let obj = make_objective(crate::config::LossKind::SmoothHinge, lam);
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
        let mut rows: Vec<(String, Vec<Option<usize>>)> = vec![
            ("dane mu=0".into(), Vec::new()),
            ("dane mu=3lam".into(), Vec::new()),
            ("admm".into(), Vec::new()),
        ];
        for &m in &ms {
            let ctx = RunCtx::new(budget).with_reference(phi_star).with_tol(1e-6);
            for (idx, mu) in [0.0, 3.0 * lam].into_iter().enumerate() {
                let mut cluster = build_cluster(
                    &ds,
                    crate::config::LossKind::SmoothHinge,
                    lam,
                    m,
                    7,
                    NetModel::free(),
                    engine,
                    topology,
                )?;
                let res = dane::run(
                    cluster.as_mut(),
                    &dane::DaneOptions { eta: 1.0, mu, ..Default::default() },
                    &ctx,
                )?;
                rows[idx].1.push(res.trace.rounds_to_tol(1e-6));
            }
            let mut cluster = build_cluster(
                &ds,
                crate::config::LossKind::SmoothHinge,
                lam,
                m,
                7,
                NetModel::free(),
                engine,
                topology,
            )?;
            // rho tuned once per workload family: consensus ADMM's rate
            // depends on rho, not on the (tiny) lambda; 0.1 is the best
            // of a coarse {0.02, 0.1, 0.5} sweep on these problems.
            let res = admm::run(
                cluster.as_mut(),
                &admm::AdmmOptions { rho: ADMM_RHO },
                &ctx,
            )?;
            rows[2].1.push(res.trace.rounds_to_tol(1e-6));
        }
        let col = Fig3Column { dataset: ds.name.clone(), ms: ms.clone(), rows };
        print_fig3_column(&col);
        write_fig3_csv(&col, &out.join(format!("{}.csv", ds.name)))?;
        columns.push(col);
    }
    Ok(columns)
}

fn print_fig3_column(col: &Fig3Column) {
    println!("fig3 [{}]  (entries: iterations to < 1e-6; * = none in budget)", col.dataset);
    print!("{:>14}", "m");
    for m in &col.ms {
        print!("{m:>6}");
    }
    println!();
    for (label, vals) in &col.rows {
        print!("{label:>14}");
        for v in vals {
            match v {
                Some(k) => print!("{k:>6}"),
                None => print!("{:>6}", "*"),
            }
        }
        println!();
    }
}

fn write_fig3_csv(col: &Fig3Column, path: &Path) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    write!(f, "algo")?;
    for m in &col.ms {
        write!(f, ",m{m}")?;
    }
    writeln!(f)?;
    for (label, vals) in &col.rows {
        write!(f, "{label}")?;
        for v in vals {
            match v {
                Some(k) => write!(f, ",{k}")?,
                None => write!(f, ",*")?,
            }
        }
        writeln!(f)?;
    }
    Ok(())
}

// ---------------------------------------------------------------------
// fig. 4 — test loss vs iteration at m = 64
// ---------------------------------------------------------------------

/// One dataset panel of fig. 4.
#[derive(Debug, Clone)]
pub struct Fig4Panel {
    pub dataset: String,
    /// (algo label, test loss per round)
    pub series: Vec<(String, Vec<f64>)>,
    /// Test loss of the exact regularized ERM ("Opt" line).
    pub opt_test_loss: f64,
}

/// Fig. 4: average regularized test loss vs iteration for m = 64 on the
/// three datasets; DANE(mu = 3 lambda), ADMM, bias-corrected OSA, and the
/// exact minimizer's level.
pub fn fig4(
    scale: usize,
    out: &Path,
    engine: EngineKind,
    topology: ExecTopology,
) -> Result<Vec<Fig4Panel>> {
    let m = 64;
    let rounds = 30;
    std::fs::create_dir_all(out)?;
    let mut panels = Vec::new();

    for (ds, lam) in fig34_datasets(scale) {
        let obj = make_objective(crate::config::LossKind::SmoothHinge, lam);
        let (w_hat, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard())?;
        let test = ds.test_shard().expect("fig4 datasets carry test splits");
        let opt_test_loss = {
            let mut rowbuf = vec![0.0; test.n()];
            obj.value(&test, &w_hat, &mut rowbuf)
        };

        let ctx = RunCtx::new(rounds)
            .with_reference(phi_star)
            .with_tol(0.0) // run the full horizon; fig4 plots the curve
            .with_test_shard(test);

        let mut series = Vec::new();
        {
            let mut cluster = build_cluster(
                &ds,
                crate::config::LossKind::SmoothHinge,
                lam,
                m,
                7,
                NetModel::free(),
                engine,
                topology,
            )?;
            let res = dane::run(
                cluster.as_mut(),
                &dane::DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() },
                &ctx,
            )?;
            series.push(("dane mu=3lam".to_string(), test_series(&res.trace)));
            emit::write_csv_file(&res.trace, &out.join(format!("{}_dane.csv", ds.name)))?;
        }
        {
            let mut cluster = build_cluster(
                &ds,
                crate::config::LossKind::SmoothHinge,
                lam,
                m,
                7,
                NetModel::free(),
                engine,
                topology,
            )?;
            let res =
                admm::run(cluster.as_mut(), &admm::AdmmOptions { rho: ADMM_RHO }, &ctx)?;
            series.push(("admm".to_string(), test_series(&res.trace)));
            emit::write_csv_file(&res.trace, &out.join(format!("{}_admm.csv", ds.name)))?;
        }
        {
            let mut cluster = build_cluster(
                &ds,
                crate::config::LossKind::SmoothHinge,
                lam,
                m,
                7,
                NetModel::free(),
                engine,
                topology,
            )?;
            let res = osa::run(
                cluster.as_mut(),
                &osa::OsaOptions { bias_correction_r: Some(0.5), seed: 3 },
                &ctx,
            )?;
            series.push(("osa-bc".to_string(), test_series(&res.trace)));
            emit::write_csv_file(&res.trace, &out.join(format!("{}_osa.csv", ds.name)))?;
        }

        println!("fig4 [{}]  opt test loss {:.6}", ds.name, opt_test_loss);
        for (label, s) in &series {
            println!(
                "  {label:>12}: first {:.6} last {:.6}",
                s.first().copied().unwrap_or(f64::NAN),
                s.last().copied().unwrap_or(f64::NAN)
            );
        }
        panels.push(Fig4Panel { dataset: ds.name.clone(), series, opt_test_loss });
    }
    Ok(panels)
}

fn test_series(trace: &Trace) -> Vec<f64> {
    trace.rows.iter().filter_map(|r| r.test_loss).collect()
}

// ---------------------------------------------------------------------
// Theorem 1 — OSA lower bound
// ---------------------------------------------------------------------

/// One (n, m) row of the Theorem-1 simulation.
#[derive(Debug, Clone)]
pub struct Thm1Row {
    pub n: usize,
    pub m: usize,
    pub lam: f64,
    pub mse_osa: f64,
    pub mse_erm: f64,
    pub subopt_osa: f64,
    pub subopt_erm: f64,
}

/// Monte-Carlo the Theorem-1 construction: lam = 1/(10 sqrt(n)), m sweeps;
/// OSA's error must plateau in m while the full ERM's decays ~1/m.
pub fn thm1(reps: usize) -> Result<Vec<Thm1Row>> {
    let n = 100;
    let lam = 1.0 / (10.0 * (n as f64).sqrt());
    let mut rows = Vec::new();
    println!("thm1: f(w;z) = lam(w^2/2 + e^w) - zw, n = {n}, lam = {lam:.4}");
    println!(
        "{:>4} {:>12} {:>12} {:>12} {:>12}",
        "m", "E(w_osa-w*)^2", "E(w_erm-w*)^2", "F-subopt osa", "F-subopt erm"
    );
    for &m in &[1usize, 4, 16, 64] {
        let e = data::thm1::estimate(lam, n, m, reps, 42);
        println!(
            "{m:>4} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            e.mse_osa, e.mse_erm, e.subopt_osa, e.subopt_erm
        );
        rows.push(Thm1Row {
            n,
            m,
            lam,
            mse_osa: e.mse_osa,
            mse_erm: e.mse_erm,
            subopt_osa: e.subopt_osa,
            subopt_erm: e.subopt_erm,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------
// Lemma 2 — Hessian concentration
// ---------------------------------------------------------------------

/// One n-row of the Lemma-2 sweep.
#[derive(Debug, Clone)]
pub struct Lemma2Row {
    pub n_per_machine: usize,
    pub max_dev: f64,
    pub bound: f64,
}

/// Empirical `max_i ||H_i - H||_2` against the Lemma-2 bound
/// `sqrt(32 L^2 log(dm/delta) / n)` on the fig. 2 quadratic.
pub fn lemma2() -> Result<Vec<Lemma2Row>> {
    let d = 64;
    let m = 8;
    let delta: f64 = 0.1;
    let paper_reg = 0.005;
    let lam = data::synthetic::fig2_lambda(paper_reg);
    let obj: Arc<dyn Objective> = Arc::new(crate::loss::Ridge::new(lam));
    let mut rows = Vec::new();
    println!("lemma2: d = {d}, m = {m} (fig. 2 covariance)");
    println!("{:>8} {:>14} {:>14} {:>8}", "n", "max||Hi-H||", "bound", "ratio");
    for &n_per in &[128usize, 512, 2048, 8192] {
        let ds = data::synthetic_fig2(n_per * m, d, paper_reg, 99);
        let cluster = SerialCluster::new(&ds, obj.clone(), m, 5);
        // H = mean of H_i (weighted equally here: equal shard sizes)
        let hs: Vec<crate::linalg::DenseMatrix> =
            cluster.workers().iter().map(|w| w.dense_hessian()).collect();
        let mut h = crate::linalg::DenseMatrix::zeros(d, d);
        for hi in &hs {
            h.add_scaled(1.0 / m as f64, hi);
        }
        let mut max_dev: f64 = 0.0;
        for hi in &hs {
            let mut diff = hi.clone();
            diff.add_scaled(-1.0, &h);
            max_dev = max_dev.max(diff.sym_spectral_norm(100, 3));
        }
        // L bounds the per-sample Hessian spectral norm: for the fig. 2
        // model E||x||^2 = sum_i i^-1.2; use the empirical max row norm.
        let l_max = max_row_sq(&ds);
        let bound =
            (32.0 * l_max * l_max * ((d * m) as f64 / delta).ln() / n_per as f64).sqrt();
        println!(
            "{n_per:>8} {max_dev:>14.6} {bound:>14.6} {:>8.3}",
            max_dev / bound
        );
        rows.push(Lemma2Row { n_per_machine: n_per, max_dev, bound });
    }
    Ok(rows)
}

fn max_row_sq(ds: &Dataset) -> f64 {
    // Representation-generic: never densifies (a 10^5-dim sparse
    // dataset must not materialize n*d zeros just to take row norms).
    let mut best: f64 = 0.0;
    for i in 0..ds.n() {
        best = best.max(ds.x.row_sq_norm(i));
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thm1_rows_show_the_gap() {
        let rows = thm1(40).unwrap();
        let m64 = rows.iter().find(|r| r.m == 64).unwrap();
        // ERM with 64x data is much better than OSA (Theorem 1).
        assert!(m64.mse_erm < m64.mse_osa / 3.0, "{m64:?}");
    }

    #[test]
    fn lemma2_deviation_shrinks_with_n() {
        let rows = lemma2().unwrap();
        assert!(rows.last().unwrap().max_dev < rows.first().unwrap().max_dev);
        // and stays under the bound
        for r in &rows {
            assert!(r.max_dev <= r.bound, "{r:?}");
        }
    }

    #[test]
    fn fig2_smoke_scale() {
        let dir = crate::util::tempdir::TempDir::new("fig2").unwrap();
        let cells = fig2(64, dir.path(), EngineKind::Serial, ExecTopology::Star).unwrap();
        assert!(!cells.is_empty());
        // DANE's contraction at the largest N should beat its contraction
        // at the smallest N for the same m (Theorem 3).
        let dane_small = cells
            .iter()
            .find(|c| c.algo == "dane" && c.m == 4 && c.n_total == 256)
            .unwrap();
        let dane_large = cells
            .iter()
            .filter(|c| c.algo == "dane" && c.m == 4)
            .max_by_key(|c| c.n_total)
            .unwrap();
        assert!(
            dane_large.mean_contraction <= dane_small.mean_contraction + 0.05,
            "large-N {} vs small-N {}",
            dane_large.mean_contraction,
            dane_small.mean_contraction
        );
    }
}
