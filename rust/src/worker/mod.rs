//! The per-machine half of the protocol.
//!
//! A [`Worker`] owns one shard and answers the coordinator's commands:
//! gradient/loss at a point, the DANE local solve (paper eq. 13), the ADMM
//! proximal step, and the per-machine ERM used by one-shot averaging. All
//! scratch is owned by the worker, so steady-state rounds allocate only
//! the result vectors they return.
//!
//! Two compute backends:
//! * **native** — pure-rust: cached-Cholesky closed form for quadratics
//!   (factor (H_i + shift I) once, reuse every round), Newton-CG otherwise;
//! * **pjrt** — the AOT HLO artifacts produced by `python/compile/aot.py`,
//!   executed through [`crate::runtime`]; shards are zero-padded to the
//!   artifact's canonical shape. Integration tests pin the two backends
//!   against each other.

pub mod backend;
pub mod local_solver;
pub mod serve;

pub use backend::WorkerBackend;

use crate::data::Shard;
use crate::linalg::ops;
use crate::loss::Objective;
use crate::solver::newton_cg::{minimize, Composite, NewtonCgOptions, NewtonCgScratch};
use crate::{Error, Result};
use local_solver::QuadCache;
use std::sync::Arc;

/// One simulated machine.
pub struct Worker {
    pub id: usize,
    shard: Shard,
    obj: Arc<dyn Objective>,
    backend: WorkerBackend,
    /// Lazily-built Gram/Cholesky cache (quadratic objectives, d small).
    quad: Option<QuadCache>,
    // scratch — everything a steady-state round needs, owned up front so
    // the per-round protocol allocates nothing (EXPERIMENTS.md §Perf)
    rowbuf: Vec<f64>,
    weights: Vec<f64>,
    newton: NewtonCgScratch,
    /// Cached-Cholesky path: delta = (H_i + mu I)^{-1} g lands here.
    solve_buf: Vec<f64>,
    /// Newton-CG path: the DANE tilt c = grad phi_i(w') - eta g.
    cbuf: Vec<f64>,
    newton_opts: NewtonCgOptions,
    /// Override for the one-time Gram-build thread count (config
    /// `threads`); None = the size ladder in `local_solver`.
    gram_threads: Option<usize>,
    /// Reply-direction compression state (error-feedback residuals +
    /// decode/compute scratch) for `Command::CompressedVec` rounds;
    /// inert unless the run compresses.
    pub(crate) comp: crate::comm::compress::WorkerCompressor,
}

impl Worker {
    pub fn new(id: usize, shard: Shard, obj: Arc<dyn Objective>) -> Self {
        let (n, d) = (shard.n(), shard.d());
        Worker {
            id,
            shard,
            obj,
            backend: WorkerBackend::Native,
            quad: None,
            rowbuf: vec![0.0; n],
            weights: vec![0.0; n],
            newton: NewtonCgScratch::new(d),
            solve_buf: vec![0.0; d],
            cbuf: vec![0.0; d],
            newton_opts: NewtonCgOptions::default(),
            gram_threads: None,
            comp: crate::comm::compress::WorkerCompressor::default(),
        }
    }

    pub fn with_backend(mut self, backend: WorkerBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Swap the compute backend in place (cluster-level backend switches).
    pub fn set_backend(&mut self, backend: WorkerBackend) {
        self.backend = backend;
    }

    pub fn shard(&self) -> &Shard {
        &self.shard
    }

    pub fn objective(&self) -> &Arc<dyn Objective> {
        &self.obj
    }

    pub fn dim(&self) -> usize {
        self.shard.d()
    }

    /// Tune the local Newton-CG budget (benches tighten/loosen this).
    pub fn set_newton_options(&mut self, opts: NewtonCgOptions) {
        self.newton_opts = opts;
    }

    /// Force the thread count of the one-time parallel Gram build
    /// (`DenseMatrix::par_gram`); None restores the size ladder. Must be
    /// set before the quadratic cache is first built to have effect —
    /// the same count on every worker keeps runs bit-reproducible.
    pub fn set_gram_threads(&mut self, threads: Option<usize>) {
        self.gram_threads = threads;
    }

    /// phi_i(w).
    pub fn loss(&mut self, w: &[f64]) -> f64 {
        self.obj.value(&self.shard, w, &mut self.rowbuf)
    }

    /// grad phi_i(w) into `out`; returns phi_i(w).
    pub fn grad(&mut self, w: &[f64], out: &mut [f64]) -> Result<f64> {
        if out.len() != self.dim() {
            return Err(Error::Shape("worker grad out".into()));
        }
        match &self.backend {
            WorkerBackend::Native => {
                Ok(self.obj.value_grad(&self.shard, w, out, &mut self.rowbuf))
            }
            WorkerBackend::Pjrt(rt) => {
                rt.grad(&self.shard, self.obj.as_ref(), w, out)
            }
        }
    }

    /// The DANE local solve (paper eq. 13):
    /// `argmin_w phi_i(w) - (grad phi_i(w') - eta g)^T w + (mu/2)||w-w'||^2`.
    ///
    /// `g` is the averaged global gradient at `w_prev`. For quadratics this
    /// is the closed form of eq. (16): `w' - eta (H_i + mu I)^{-1} g`,
    /// served by the cached factorization.
    pub fn dane_local_solve(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.dane_local_solve_into(w_prev, g, eta, mu, &mut out)?;
        Ok(out)
    }

    /// [`Worker::dane_local_solve`] into a caller-owned buffer — the
    /// worker half of the zero-allocation round protocol. On the cached
    /// quadratic path a steady-state call touches no heap: the factor is
    /// memoized, delta lands in worker scratch, and the result reuses
    /// `out`'s existing capacity (the coordinator recycles these buffers
    /// round over round).
    pub fn dane_local_solve_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut Vec<f64>,
    ) -> Result<()> {
        if let WorkerBackend::Pjrt(rt) = &self.backend {
            let w =
                rt.dane_local_solve(&self.shard, self.obj.as_ref(), w_prev, g, eta, mu)?;
            out.clear();
            out.extend_from_slice(&w);
            return Ok(());
        }
        if self.obj.is_quadratic() && self.quad_usable() {
            // delta = (H_i + mu I)^{-1} g ; w_i = w_prev - eta * delta
            let shift = self.obj.lambda() + mu;
            let mut delta = std::mem::take(&mut self.solve_buf);
            let cache = self.quad_cache()?;
            let solved = cache.solve_shifted_into(shift, g, &mut delta);
            if let Err(e) = solved {
                self.solve_buf = delta;
                return Err(e);
            }
            out.clear();
            out.extend_from_slice(w_prev);
            ops::axpy(-eta, &delta, out);
            self.solve_buf = delta;
            return Ok(());
        }
        // General path: Newton-CG on the composite. c = grad phi_i(w') - eta g.
        let d = self.dim();
        let mut c = std::mem::take(&mut self.cbuf);
        c.clear();
        c.resize(d, 0.0);
        self.obj
            .value_grad(&self.shard, w_prev, &mut c, &mut self.rowbuf);
        ops::axpy(-eta, g, &mut c);
        out.clear();
        out.extend_from_slice(w_prev);
        let problem = Composite {
            obj: self.obj.as_ref(),
            shard: &self.shard,
            c: Some(&c),
            mu,
            w0: Some(w_prev),
        };
        let res = minimize(
            &problem,
            out,
            &self.newton_opts,
            &mut self.rowbuf,
            &mut self.weights,
            &mut self.newton,
        );
        self.cbuf = c;
        res?;
        Ok(())
    }

    /// ADMM proximal step: `argmin_w phi_i(w) + (rho/2)||w - v||^2`.
    pub fn admm_prox(&mut self, v: &[f64], rho: f64) -> Result<Vec<f64>> {
        if self.obj.is_quadratic() && self.quad_usable() {
            // (H_i + rho I) w = b_i + rho v, b_i = (1/n) X^T y
            let shift = self.obj.lambda() + rho;
            let cache = self.quad_cache()?;
            let mut rhs = cache.xty().to_vec();
            ops::axpy(rho, v, &mut rhs);
            return cache.solve_shifted(shift, &rhs);
        }
        let problem = Composite {
            obj: self.obj.as_ref(),
            shard: &self.shard,
            c: None,
            mu: rho,
            w0: Some(v),
        };
        let mut w = v.to_vec();
        minimize(
            &problem,
            &mut w,
            &self.newton_opts,
            &mut self.rowbuf,
            &mut self.weights,
            &mut self.newton,
        )?;
        Ok(w)
    }

    /// Per-machine ERM `argmin phi_i(w)` (one-shot averaging, eq. 6).
    pub fn local_erm(&mut self) -> Result<Vec<f64>> {
        if self.obj.is_quadratic() && self.quad_usable() {
            let shift = self.obj.lambda();
            let cache = self.quad_cache()?;
            let rhs = cache.xty().to_vec();
            return cache.solve_shifted(shift, &rhs);
        }
        let problem = Composite {
            obj: self.obj.as_ref(),
            shard: &self.shard,
            c: None,
            mu: 0.0,
            w0: None,
        };
        let mut w = vec![0.0; self.dim()];
        minimize(
            &problem,
            &mut w,
            &self.newton_opts,
            &mut self.rowbuf,
            &mut self.weights,
            &mut self.newton,
        )?;
        Ok(w)
    }

    /// ERM over a without-replacement subsample of `r * n` rows — the
    /// Zhang et al. bias-correction helper.
    pub fn local_erm_subsample(&mut self, r: f64, seed: u64) -> Result<Vec<f64>> {
        if !(0.0 < r && r < 1.0) {
            return Err(Error::Config("subsample r must be in (0,1)".into()));
        }
        let n = self.shard.n_effective();
        let take = ((r * n as f64).round() as usize).clamp(1, n);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut rng =
            crate::util::Rng64::seed_from_u64(seed ^ ((self.id as u64) << 32));
        rng.shuffle(&mut idx);
        idx.truncate(take);
        let sub = Shard::new(
            self.shard.x.take_rows(&idx),
            idx.iter().map(|&i| self.shard.y[i]).collect(),
        );
        let problem = Composite {
            obj: self.obj.as_ref(),
            shard: &sub,
            c: None,
            mu: 0.0,
            w0: None,
        };
        let mut w = vec![0.0; self.dim()];
        let mut rowbuf = vec![0.0; sub.n()];
        let mut weights = vec![0.0; sub.n()];
        minimize(
            &problem,
            &mut w,
            &self.newton_opts,
            &mut rowbuf,
            &mut weights,
            &mut self.newton,
        )?;
        Ok(w)
    }

    /// Local Hessian `H_i = (1/n) X^T X + lam I` as a dense matrix
    /// (Lemma-2 diagnostics; quadratic objectives, moderate d only).
    pub fn dense_hessian(&self) -> crate::linalg::DenseMatrix {
        let n = self.shard.n_effective() as f64;
        let mut h = self.shard.x.gram();
        for i in 0..h.rows() {
            for j in 0..h.cols() {
                let v = h.get(i, j) / n;
                h.set(i, j, v);
            }
        }
        h.add_diag(self.obj.lambda())
    }

    /// Whether the cached-Cholesky path applies: a **dense** shard of
    /// moderate dimension. Sparse shards take the matrix-free Newton-CG
    /// path at any d — a d x d dense Gram of a 10^5-dimensional sparse
    /// dataset would be 80 GB, and the CG HVPs cost O(nnz) instead.
    fn quad_usable(&self) -> bool {
        matches!(self.shard.x, crate::linalg::DataMatrix::Dense(_))
            && self.dim() <= local_solver::CHOLESKY_MAX_DIM
    }

    /// Whether the dense Gram/Cholesky cache has actually been built —
    /// diagnostics for tests pinning the Hessian-free fallback above
    /// [`local_solver::CHOLESKY_MAX_DIM`].
    pub fn quad_cache_built(&self) -> bool {
        self.quad.is_some()
    }

    fn quad_cache(&mut self) -> Result<&mut QuadCache> {
        if self.quad.is_none() {
            self.quad =
                Some(QuadCache::build_with_threads(&self.shard, self.gram_threads)?);
        }
        self.quad.as_mut().ok_or_else(|| {
            crate::Error::Runtime("quad cache vanished after build".into())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::loss::testutil::{class_shard, reg_shard};
    use crate::loss::{Ridge, SmoothHinge};

    #[test]
    fn grad_matches_objective() {
        let shard = reg_shard(40, 6, 1);
        let obj = Arc::new(Ridge::new(0.05));
        let mut w = Worker::new(0, shard.clone(), obj.clone());
        let point = vec![0.1; 6];
        let mut g1 = vec![0.0; 6];
        let v1 = w.grad(&point, &mut g1).unwrap();
        let mut g2 = vec![0.0; 6];
        let mut rb = vec![0.0; 40];
        let v2 = obj.value_grad(&shard, &point, &mut g2, &mut rb);
        assert_eq!(g1, g2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn quadratic_dane_solve_matches_newton_cg_path() {
        let shard = reg_shard(50, 8, 3);
        let obj = Arc::new(Ridge::new(0.1));
        let mut w = Worker::new(0, shard.clone(), obj.clone());
        let w_prev = vec![0.3; 8];
        let mut g = vec![0.0; 8];
        w.grad(&w_prev, &mut g).unwrap();
        let fast = w.dane_local_solve(&w_prev, &g, 1.0, 0.5).unwrap();

        // reference through the generic composite solver
        let mut c = vec![0.0; 8];
        let mut rb = vec![0.0; 50];
        obj.value_grad(&shard, &w_prev, &mut c, &mut rb);
        ops::axpy(-1.0, &g, &mut c);
        let problem = Composite {
            obj: obj.as_ref(),
            shard: &shard,
            c: Some(&c),
            mu: 0.5,
            w0: Some(&w_prev),
        };
        let mut slow = w_prev.clone();
        let mut weights = vec![0.0; 50];
        let mut scratch = NewtonCgScratch::new(8);
        minimize(&problem, &mut slow, &NewtonCgOptions::default(), &mut rb, &mut weights, &mut scratch)
            .unwrap();
        for j in 0..8 {
            assert!((fast[j] - slow[j]).abs() < 1e-7, "{} vs {}", fast[j], slow[j]);
        }
    }

    #[test]
    fn admm_prox_optimality() {
        let shard = class_shard(60, 5, 7);
        let obj = Arc::new(SmoothHinge::new(0.01));
        let mut wk = Worker::new(0, shard.clone(), obj.clone());
        let v = vec![0.2, -0.1, 0.0, 0.4, -0.3];
        let rho = 2.0;
        let w = wk.admm_prox(&v, rho).unwrap();
        // optimality: grad phi_i(w) + rho (w - v) = 0
        let mut g = vec![0.0; 5];
        let mut rb = vec![0.0; 60];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        for j in 0..5 {
            assert!((g[j] + rho * (w[j] - v[j])).abs() < 1e-8);
        }
    }

    #[test]
    fn local_erm_is_stationary() {
        let shard = class_shard(80, 4, 11);
        let obj = Arc::new(SmoothHinge::new(0.05));
        let mut wk = Worker::new(0, shard.clone(), obj.clone());
        let w = wk.local_erm().unwrap();
        let mut g = vec![0.0; 4];
        let mut rb = vec![0.0; 80];
        obj.value_grad(&shard, &w, &mut g, &mut rb);
        assert!(ops::norm2(&g) < 1e-9);
    }

    #[test]
    fn subsample_erm_uses_fewer_rows() {
        let shard = reg_shard(100, 3, 13);
        let obj = Arc::new(Ridge::new(0.5));
        let mut wk = Worker::new(0, shard, obj);
        let w_half = wk.local_erm_subsample(0.5, 99).unwrap();
        let w_full = wk.local_erm().unwrap();
        // different data -> different optimum (almost surely)
        assert!(ops::dist2(&w_half, &w_full) > 1e-8);
        assert!(wk.local_erm_subsample(1.5, 0).is_err());
    }

    #[test]
    fn dense_hessian_shape() {
        let shard = reg_shard(30, 6, 17);
        let w = Worker::new(0, shard, Arc::new(Ridge::new(0.25)));
        let h = w.dense_hessian();
        assert_eq!(h.rows(), 6);
        // diagonal includes lambda
        assert!(h.get(0, 0) >= 0.25);
    }

    #[test]
    fn solve_into_reuses_out_buffer() {
        let shard = reg_shard(50, 8, 3);
        let obj = Arc::new(Ridge::new(0.1));
        let mut wk = Worker::new(0, shard, obj);
        let w_prev = vec![0.3; 8];
        let mut g = vec![0.0; 8];
        wk.grad(&w_prev, &mut g).unwrap();
        let direct = wk.dane_local_solve(&w_prev, &g, 1.0, 0.5).unwrap();
        let mut out = Vec::new();
        wk.dane_local_solve_into(&w_prev, &g, 1.0, 0.5, &mut out).unwrap();
        assert_eq!(out, direct);
        let cap = out.capacity();
        wk.dane_local_solve_into(&w_prev, &g, 1.0, 0.5, &mut out).unwrap();
        assert_eq!(out, direct);
        assert_eq!(out.capacity(), cap, "steady-state solve must not regrow out");
    }

    #[test]
    fn falls_back_to_newton_cg_above_cholesky_max_dim() {
        use crate::linalg::{DataMatrix, DenseMatrix};
        // few rows, d just past the cap: the dense d x d Gram must never
        // be materialized; lam > 0 keeps the composite strongly convex
        let d = local_solver::CHOLESKY_MAX_DIM + 1;
        let n = 6;
        let mut rng = crate::util::Rng64::seed_from_u64(5);
        let mut x = DenseMatrix::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                x.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        let y: Vec<f64> = (0..n).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let shard = Shard::new(DataMatrix::Dense(x), y);
        let obj = Arc::new(Ridge::new(0.1));
        let mut wk = Worker::new(0, shard, obj.clone());
        let w_prev = vec![0.0; d];
        let mut g = vec![0.0; d];
        wk.grad(&w_prev, &mut g).unwrap();
        let (eta, mu) = (1.0, 0.5);
        let w1 = wk.dane_local_solve(&w_prev, &g, eta, mu).unwrap();
        assert!(
            !wk.quad_cache_built(),
            "d > CHOLESKY_MAX_DIM must take the Hessian-free Newton-CG path"
        );
        // DANE local optimality: grad phi(w1) - c + mu (w1 - w') = 0 with
        // c = grad phi_i(w') - eta g = 0 here (phi_i = phi, eta = 1)
        let mut g1 = vec![0.0; d];
        wk.grad(&w1, &mut g1).unwrap();
        let mut resid: f64 = 0.0;
        for j in 0..d {
            let r = g1[j] + mu * (w1[j] - w_prev[j]);
            resid += r * r;
        }
        assert!(resid.sqrt() < 1e-7, "stationarity residual {}", resid.sqrt());
    }

    #[test]
    fn sparse_shard_takes_matrix_free_path_below_the_dim_cap() {
        use crate::linalg::{CsrMatrix, DataMatrix};
        // d well under CHOLESKY_MAX_DIM: the *representation*, not the
        // dimension, must route a sparse quadratic shard to Newton-CG —
        // the dense Gram/Cholesky cache is never built
        let (n, d) = (40usize, 12usize);
        let mut rng = crate::util::Rng64::seed_from_u64(9);
        let mut trips = Vec::new();
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            for k in 0..3 {
                let j = (i * 5 + k * 7) % d;
                trips.push((i, j, rng.range_f64(-1.0, 1.0)));
            }
            y.push(rng.range_f64(-1.0, 1.0));
        }
        let shard = Shard::new(
            DataMatrix::Sparse(CsrMatrix::from_triplets(n, d, &trips)),
            y,
        );
        let obj = Arc::new(Ridge::new(0.1));
        let mut wk = Worker::new(0, shard, obj);
        let w_prev = vec![0.0; d];
        let mut g = vec![0.0; d];
        wk.grad(&w_prev, &mut g).unwrap();
        let mu = 0.5;
        let w1 = wk.dane_local_solve(&w_prev, &g, 1.0, mu).unwrap();
        assert!(
            !wk.quad_cache_built(),
            "sparse shards must never build the dense Gram/Cholesky cache"
        );
        // same DANE local stationarity condition as the dense-d test
        let mut g1 = vec![0.0; d];
        wk.grad(&w1, &mut g1).unwrap();
        let mut resid: f64 = 0.0;
        for j in 0..d {
            let r = g1[j] + mu * (w1[j] - w_prev[j]);
            resid += r * r;
        }
        assert!(resid.sqrt() < 1e-7, "stationarity residual {}", resid.sqrt());
        // the other quad-gated entry points stay matrix-free too
        wk.admm_prox(&vec![0.1; d], 1.0).unwrap();
        wk.local_erm().unwrap();
        assert!(!wk.quad_cache_built());
    }
}
