//! Worker compute backends.

use crate::runtime::PjrtSession;
use std::sync::Arc;

/// How a worker executes its local computations.
///
/// * `Native` — pure-rust linalg: cached-Cholesky closed forms for
///   quadratics, Newton-CG otherwise. Works for any shape and loss.
/// * `Pjrt` — the AOT HLO artifacts (L2 jax graphs over L1 Pallas
///   kernels) executed through the PJRT CPU client. Demonstrates the
///   production split: Python authored the compute once at build time;
///   the request path is rust -> PJRT only.
pub enum WorkerBackend {
    Native,
    Pjrt(Arc<PjrtSession>),
}

impl std::fmt::Debug for WorkerBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkerBackend::Native => write!(f, "Native"),
            WorkerBackend::Pjrt(s) => {
                let (n, d) = s.padded_shape();
                write!(f, "Pjrt(padded {n}x{d})")
            }
        }
    }
}
