//! The worker side of the wire protocol: one command in, one reply out —
//! plus, under the tree topology, the relay plane that moves frames
//! down to child workers and ordered reply bundles back up.
//!
//! [`execute_command`] is the single implementation of every collective a
//! worker answers — the threaded engine calls it from its per-worker
//! thread loop and the TCP engine calls it from the serve session, so
//! the transports cannot drift apart semantically.
//!
//! [`serve_addr`] is the process entry point behind `dane worker
//! --listen <addr>`: bind, announce the bound address on stdout
//! (`listening on <addr>` — the self-hosted leader parses this line to
//! learn OS-assigned ports), then serve leader sessions in a loop:
//! answer frames until the leader hangs up, go back to accepting (so a
//! supervising leader can redial after a fault); `--once` exits after
//! the first session instead. The worker learns everything else — shard,
//! objective, Gram-thread override — from the leader's
//! [`Command::Init`] frame, so a worker process needs no config file.
//!
//! ## Tree relay ([`Command::Peers`])
//!
//! Under `topology: "tree"` the leader additionally sends every worker a
//! `Peers` frame naming its child workers (rank, address, and the
//! preorder rank list of each child's subtree). The worker opens one
//! round connection per child; interior workers whose parent is another
//! worker ack with `expect_parent` set, after which the leader closes
//! the setup connection and the worker **accepts its parent's
//! connection from its own listener** (the parent dialed it while
//! handling its own `Peers`; the OS accept backlog makes the ordering
//! race-free). From then on each round is:
//!
//! 1. read one command frame from the parent,
//! 2. relay the raw frame to every child (they start computing first),
//! 3. execute locally and send the own reply up,
//! 4. pump exactly `ranks.len()` reply frames per child upward, in
//!    child order — the preorder bundle the parent (ultimately the
//!    leader) attributes to ranks positionally.
//!
//! A dead child never breaks the frame-count discipline: the relay
//! synthesizes a [`Reply::Err`] frame for every reply the child still
//! owed, so the leader drains a failed round completely and surfaces
//! the error instead of hanging. [`Command::For`] frames are routed
//! point-to-point toward their target rank with a single reply piped
//! back; no other subtree worker is touched.
//!
//! Errors on the compute path become [`Reply::Err`] frames (the leader
//! maps them to `Error::Runtime` and the algorithms to `AlgoError`);
//! only transport failures on the *upstream* connection tear the loop
//! down. Nothing here panics on malformed input.

use crate::comm::compress::{self, CompressedOp, WorkerCompressor};
use crate::comm::topology::RELAY_CHILD_LOST;
use crate::comm::wire::{self, Command, InitPayload, InitRefPayload, PeersPayload, Reply};
use crate::config::LossKind;
use crate::loss::make_objective;
use crate::worker::Worker;
use crate::{Error, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};

/// Reject a payload vector whose length does not match the shard
/// dimension. A frame can be perfectly well-formed at the codec level
/// and still carry a wrong-size vector (a buggy or hostile leader); the
/// objectives index rows against `w` unchecked in release builds, so
/// this is the line that keeps "malformed input never panics a worker"
/// true end to end.
fn dim_check(what: &str, len: usize, d: usize) -> Option<Reply> {
    if len != d {
        Some(Reply::Err(format!(
            "{what}: payload has {len} elements, shard dimension is {d}"
        )))
    } else {
        None
    }
}

/// Answer one compute command. `Init`/`Peers` are transport setup, not
/// compute — transports that construct their workers directly (threaded)
/// or that handle the handshake themselves (TCP, in the serve session)
/// never route them here, so they answer with an error reply.
pub fn execute_command(worker: &mut Worker, cmd: Command) -> Reply {
    let d = worker.dim();
    match cmd {
        Command::Init(_) | Command::InitRef(_) => {
            Reply::Err("init sent to an already-initialized worker".into())
        }
        Command::Peers(_) => {
            Reply::Err("peers sent to the compute layer".into())
        }
        Command::For { rank, inner } => {
            // Routing lives in the relay loops; by the time an envelope
            // reaches the compute layer it must address this worker.
            if rank == worker.id {
                execute_command(worker, *inner)
            } else {
                Reply::Err(format!(
                    "misrouted For: targets worker {rank}, reached {}",
                    worker.id
                ))
            }
        }
        Command::GradLoss { w, mut out } => {
            if let Some(err) = dim_check("grad_loss", w.len(), d) {
                return err;
            }
            if out.len() != d {
                out.clear();
                out.resize(d, 0.0);
            }
            match worker.grad(&w, &mut out) {
                Ok(loss) => Reply::VecScalar(out, loss),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Loss { w } => match dim_check("loss", w.len(), d) {
            Some(err) => err,
            None => Reply::Scalar(worker.loss(&w)),
        },
        Command::DaneSolve { w_prev, g, eta, mu, mut out } => {
            if let Some(err) = dim_check("dane_solve w_prev", w_prev.len(), d) {
                return err;
            }
            if let Some(err) = dim_check("dane_solve g", g.len(), d) {
                return err;
            }
            match worker.dane_local_solve_into(&w_prev, &g, eta, mu, &mut out) {
                Ok(()) => Reply::Vec(out),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Prox { v, rho } => {
            if let Some(err) = dim_check("prox", v.len(), d) {
                return err;
            }
            match worker.admm_prox(&v, rho) {
                Ok(w) => Reply::Vec(w),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::ProxAll { targets, rho } => {
            let Some(v) = targets.get(worker.id) else {
                return Reply::Err(format!(
                    "prox_all: {} targets, none for worker {}",
                    targets.len(),
                    worker.id
                ));
            };
            if let Some(err) = dim_check("prox_all", v.len(), d) {
                return err;
            }
            match worker.admm_prox(v, rho) {
                Ok(w) => Reply::Vec(w),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Erm { subsample } => match worker.local_erm() {
            Err(e) => Reply::Err(e.to_string()),
            Ok(full) => match subsample {
                None => Reply::VecPair(full, None),
                Some((r, seed)) => match worker.local_erm_subsample(r, seed) {
                    Ok(sub) => Reply::VecPair(full, Some(sub)),
                    Err(e) => Reply::Err(e.to_string()),
                },
            },
        },
        Command::RowSq => {
            let sh = worker.shard();
            let mut total = 0.0;
            for i in 0..sh.n_effective() {
                total += crate::coordinator::row_sq_norm(sh, i);
            }
            Reply::Scalar(total / sh.n_effective() as f64)
        }
        Command::CompressedVec(p) => execute_compressed(worker, &p),
    }
}

/// Answer one compressed round command: reconstruct the broadcast
/// vectors into worker-owned scratch, run the same compute the
/// uncompressed command would, then compress the reply per the command's
/// spec — through this worker's error-feedback stream when the spec asks
/// for it. Shared by both concurrent engines (like everything in
/// [`execute_command`]), so compressed rounds cannot drift between them.
fn execute_compressed(worker: &mut Worker, p: &compress::CompressedCmd) -> Reply {
    let d = worker.dim();
    // Take the compressor out of the worker so its scratch buffers can be
    // borrowed alongside `&mut Worker` compute calls, then put it back
    // (the residuals must persist across rounds).
    let mut comp = std::mem::take(&mut worker.comp);
    let reply = run_compressed(worker, &mut comp, p, d);
    worker.comp = comp;
    reply
}

fn run_compressed(
    worker: &mut Worker,
    comp: &mut WorkerCompressor,
    p: &compress::CompressedCmd,
    d: usize,
) -> Reply {
    let rank = worker.id as u64;
    match p.op {
        CompressedOp::GradLoss => {
            let Some(w) = p.vecs.first() else {
                return Reply::Err("compressed grad_loss: missing iterate".into());
            };
            if let Some(err) = dim_check("compressed grad_loss", w.dim(), d) {
                return err;
            }
            w.decode_into(&mut comp.w_buf);
            comp.out.clear();
            comp.out.resize(d, 0.0);
            let loss = match worker.grad(&comp.w_buf, &mut comp.out) {
                Ok(loss) => loss,
                Err(e) => return Reply::Err(e.to_string()),
            };
            let out = std::mem::take(&mut comp.out);
            let vec = comp.encode_reply(CompressedOp::GradLoss, &p.spec, rank, &out);
            comp.out = out;
            Reply::CompressedVec(Box::new(compress::CompressedReply {
                loss: Some(loss),
                vec,
            }))
        }
        CompressedOp::DaneSolve => {
            let (Some(w_prev), Some(g)) = (p.vecs.first(), p.vecs.get(1)) else {
                return Reply::Err("compressed dane_solve: missing vectors".into());
            };
            if let Some(err) = dim_check("compressed dane_solve w_prev", w_prev.dim(), d)
            {
                return err;
            }
            if let Some(err) = dim_check("compressed dane_solve g", g.dim(), d) {
                return err;
            }
            w_prev.decode_into(&mut comp.w_buf);
            g.decode_into(&mut comp.g_buf);
            let mut out = std::mem::take(&mut comp.out);
            let solved = worker.dane_local_solve_into(
                &comp.w_buf,
                &comp.g_buf,
                p.eta,
                p.mu,
                &mut out,
            );
            if let Err(e) = solved {
                comp.out = out;
                return Reply::Err(e.to_string());
            }
            let vec = comp.encode_reply(CompressedOp::DaneSolve, &p.spec, rank, &out);
            comp.out = out;
            Reply::CompressedVec(Box::new(compress::CompressedReply { loss: None, vec }))
        }
    }
}

/// Build a worker from an [`Command::Init`] payload.
fn build_worker(p: InitPayload) -> Result<Worker> {
    let kind = LossKind::from_name(&p.loss_name)?;
    let obj = make_objective(kind, p.lambda);
    let mut w = Worker::new(p.worker_id, p.shard, obj);
    w.set_gram_threads(p.gram_threads);
    Ok(w)
}

/// Build a worker from an [`Command::InitRef`] payload: recompute this
/// rank's row list with the same deterministic shuffle every engine
/// uses and stream exactly those rows from the named LIBSVM file. The
/// decode layer already validated the sharding parameters
/// (`worker_id < machines <= n`), so `shard_indices` cannot panic here;
/// a wrong or missing file surfaces as an `Err` → `Reply::Err` ack.
fn build_worker_by_ref(p: InitRefPayload) -> Result<Worker> {
    let kind = LossKind::from_name(&p.loss_name)?;
    let obj = make_objective(kind, p.lambda);
    let rows = crate::data::shard_indices(p.n, p.machines, p.shard_seed);
    let mine = &rows[p.worker_id];
    let (x, y) =
        crate::data::libsvm::load_rows(std::path::Path::new(&p.path), p.dim, mine)?;
    let shard = crate::data::Shard::new(crate::linalg::DataMatrix::Sparse(x), y);
    let mut w = Worker::new(p.worker_id, shard, obj);
    w.set_gram_threads(p.gram_threads);
    Ok(w)
}

/// `dane worker --listen <addr>`: bind, announce, then serve leader
/// sessions in a loop — after a leader hangs up (or the session dies on
/// a transport error) the worker returns to `accept` on the same bound
/// listener, so a supervising leader can redial it after a fault
/// without the operator restarting anything. `once` restores the old
/// single-session behavior (exit after the first leader is done).
pub fn serve_addr(addr: &str, once: bool) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Runtime(format!("worker: bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| Error::Runtime(format!("worker: local_addr: {e}")))?;
    // The self-hosted leader reads this exact line to learn the port
    // when the operator (or harness) asked for :0.
    println!("listening on {local}");
    std::io::stdout().flush()?;
    serve_loop(listener, once)
}

/// Serve leader sessions in a loop on an already-bound listener — the
/// in-process form of [`serve_addr`]'s accept loop (no announce line).
/// A session that dies on a transport error ends that session only; the
/// worker returns to `accept`. `once` exits after the first session.
pub fn serve_loop(listener: TcpListener, once: bool) -> Result<()> {
    loop {
        let (stream, _peer) = listener
            .accept()
            .map_err(|e| Error::Runtime(format!("worker: accept: {e}")))?;
        // Session state (worker, relay links) is per-session: a redialed
        // leader re-Inits from scratch, exactly like a fresh process.
        if let Err(e) = serve_session(stream, Some(&listener)) {
            eprintln!("worker: session ended: {e}");
        }
        if once {
            return Ok(());
        }
    }
}

/// Accept one leader connection on an already-bound listener and serve
/// it, keeping the listener alive so a tree parent can be accepted
/// later. No announce line — in-process workers (benches, tests) bind
/// their own listeners and already know the address.
pub fn serve_listener(listener: TcpListener) -> Result<()> {
    let (stream, _peer) = listener
        .accept()
        .map_err(|e| Error::Runtime(format!("worker: accept: {e}")))?;
    serve_session(stream, Some(&listener))
}

/// Frame loop over an accepted leader connection with no retained
/// listener — star topologies only (a `Peers` frame asking this worker
/// to await a tree parent is answered with an error, since there is no
/// listener to accept the parent on).
pub fn serve_conn(stream: TcpStream) -> Result<()> {
    serve_session(stream, None)
}

/// One downstream relay link.
struct ChildLink {
    rank: usize,
    /// Preorder ranks of the child's subtree: replies owed per round.
    ranks: Vec<usize>,
    /// `None` once the link died; the pump synthesizes `Reply::Err`
    /// frames in its place so the count discipline holds.
    stream: Option<TcpStream>,
}

/// Write one frame (length prefix + `body`) to `w` as a **single
/// vectored write** — prefix and body leave in one syscall on the happy
/// path instead of two (the second of which a non-NODELAY stack would
/// otherwise delay). `write_vectored` has no all-or-nothing contract, so
/// the loop re-slices by hand on a short write; a zero-length write is
/// surfaced as `WriteZero` like `write_all` would.
fn write_raw<W: Write>(w: &mut W, body: &[u8]) -> std::io::Result<()> {
    let len = (body.len() as u32).to_le_bytes();
    let total = len.len() + body.len();
    let mut done = 0usize;
    while done < total {
        let res = if done < len.len() {
            w.write_vectored(&[
                std::io::IoSlice::new(&len[done..]),
                std::io::IoSlice::new(body),
            ])
        } else {
            w.write(&body[done - len.len()..])
        };
        match res {
            Ok(0) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::WriteZero,
                    "failed to write whole frame",
                ))
            }
            Ok(n) => done += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Encode `reply` into `enc` and write it to `up`; upstream write
/// failures are fatal for the session.
fn send_reply(up: &mut TcpStream, enc: &mut Vec<u8>, reply: &Reply) -> Result<()> {
    wire::encode_reply(reply, enc)?;
    up.write_all(enc.as_slice())
        .map_err(|e| Error::Runtime(format!("worker: reply write: {e}")))
}

/// The frame loop: leader handshake (`Init`, optionally `Peers`),
/// optional parent takeover, then rounds — executing, relaying, and
/// bundling as the topology demands. Returns `Ok(())` on a clean
/// upstream hangup (EOF at a frame boundary), `Err` on transport
/// failure. Compute errors never end the loop — they travel back as
/// [`Reply::Err`] frames.
fn serve_session(stream: TcpStream, listener: Option<&TcpListener>) -> Result<()> {
    let mut up = stream;
    up.set_nodelay(true)
        .map_err(|e| Error::Runtime(format!("worker: set_nodelay: {e}")))?;
    let mut frame = Vec::new();
    let mut childbuf = Vec::new();
    let mut enc = Vec::new();
    let mut worker: Option<Worker> = None;
    let mut children: Vec<ChildLink> = Vec::new();
    let mut awaiting_parent = false;
    loop {
        match wire::read_frame(&mut up, &mut frame)? {
            Some(_) => {}
            None => {
                if awaiting_parent {
                    // The leader closed the setup connection; the round
                    // plane continues on the parent's connection, which
                    // the parent dialed while handling its own Peers.
                    let listener = listener.ok_or_else(|| {
                        Error::Runtime("worker: no listener for parent".into())
                    })?;
                    let (parent, _peer) = listener.accept().map_err(|e| {
                        Error::Runtime(format!("worker: accept parent: {e}"))
                    })?;
                    up = parent;
                    up.set_nodelay(true).map_err(|e| {
                        Error::Runtime(format!("worker: set_nodelay: {e}"))
                    })?;
                    awaiting_parent = false;
                    continue;
                }
                return Ok(()); // upstream hung up between rounds
            }
        }
        match wire::decode_command(&frame) {
            Err(e) => send_reply(&mut up, &mut enc, &Reply::Err(e.to_string()))?,
            Ok(Command::Init(p)) => {
                let reply = match build_worker(*p) {
                    Ok(w) => {
                        worker = Some(w);
                        Reply::Scalar(0.0) // init ack
                    }
                    Err(e) => Reply::Err(e.to_string()),
                };
                send_reply(&mut up, &mut enc, &reply)?;
            }
            Ok(Command::InitRef(p)) => {
                let reply = match build_worker_by_ref(*p) {
                    Ok(w) => {
                        worker = Some(w);
                        Reply::Scalar(0.0) // init ack
                    }
                    Err(e) => Reply::Err(e.to_string()),
                };
                send_reply(&mut up, &mut enc, &reply)?;
            }
            Ok(Command::Peers(p)) => {
                let reply = match install_peers(&mut children, *p, listener.is_some()) {
                    Ok(expect_parent) => {
                        awaiting_parent = expect_parent;
                        Reply::Scalar(0.0) // peers ack
                    }
                    Err(e) => Reply::Err(e.to_string()),
                };
                send_reply(&mut up, &mut enc, &reply)?;
            }
            Ok(Command::For { rank, inner }) => {
                let own = worker.as_ref().map(|w| w.id);
                if own == Some(rank) {
                    let reply = match worker.as_mut() {
                        Some(w) => execute_command(w, *inner),
                        None => Reply::Err("worker not initialized".into()),
                    };
                    send_reply(&mut up, &mut enc, &reply)?;
                } else {
                    relay_for(&mut up, &mut children, rank, &frame, &mut childbuf, &mut enc)?;
                }
            }
            Ok(cmd) => {
                // Broadcast round: children first (they start computing
                // while this worker does), own compute + reply, then the
                // preorder bundle pump.
                relay_down(&mut children, &frame);
                let reply = match worker.as_mut() {
                    Some(w) => execute_command(w, cmd),
                    None => Reply::Err("worker not initialized (no Init frame)".into()),
                };
                send_reply(&mut up, &mut enc, &reply)?;
                pump_children(&mut up, &mut children, &mut childbuf, &mut enc)?;
            }
        }
    }
}

/// Open the round connections a `Peers` frame names. Returns the
/// `expect_parent` flag on success; any child connect failure is
/// reported (the leader aborts bring-up on a failed peers ack).
fn install_peers(
    children: &mut Vec<ChildLink>,
    p: PeersPayload,
    have_listener: bool,
) -> Result<bool> {
    if p.expect_parent && !have_listener {
        return Err(Error::Runtime(
            "worker has no listener to accept a tree parent on".into(),
        ));
    }
    let mut links = Vec::with_capacity(p.children.len());
    for c in p.children {
        let stream = TcpStream::connect(&c.addr).map_err(|e| {
            Error::Runtime(format!("connect child worker {} at {}: {e}", c.rank, c.addr))
        })?;
        stream.set_nodelay(true).map_err(|e| {
            Error::Runtime(format!("child worker {} set_nodelay: {e}", c.rank))
        })?;
        links.push(ChildLink { rank: c.rank, ranks: c.ranks, stream: Some(stream) });
    }
    *children = links;
    Ok(p.expect_parent)
}

/// Relay the raw command frame in `body` to every live child; a failed
/// write kills that link (its replies are synthesized by the pump).
fn relay_down(children: &mut [ChildLink], body: &[u8]) {
    for c in children.iter_mut() {
        if let Some(stream) = &mut c.stream {
            if write_raw(stream, body).is_err() {
                c.stream = None;
            }
        }
    }
}

/// Route a `For` frame toward the child whose subtree holds `rank` and
/// pipe the single reply back up.
fn relay_for(
    up: &mut TcpStream,
    children: &mut [ChildLink],
    rank: usize,
    body: &[u8],
    childbuf: &mut Vec<u8>,
    enc: &mut Vec<u8>,
) -> Result<()> {
    let Some(c) = children.iter_mut().find(|c| c.ranks.contains(&rank)) else {
        return send_reply(
            up,
            enc,
            &Reply::Err(format!("unroutable For: no subtree holds worker {rank}")),
        );
    };
    let relayed = match &mut c.stream {
        None => None,
        Some(stream) => {
            if write_raw(stream, body).is_err() {
                None
            } else {
                match wire::read_frame(stream, childbuf) {
                    Ok(Some(_)) => Some(()),
                    _ => None,
                }
            }
        }
    };
    match relayed {
        Some(()) => write_raw(up, childbuf)
            .map_err(|e| Error::Runtime(format!("worker: relay write: {e}"))),
        None => {
            c.stream = None;
            // RELAY_CHILD_LOST prefix: the leader classifies this reply
            // as a recoverable transport loss, not a compute error.
            let msg = format!(
                "{RELAY_CHILD_LOST} {} died mid-round (For toward worker {rank})",
                c.rank
            );
            send_reply(up, enc, &Reply::Err(msg))
        }
    }
}

/// Forward each child's preorder reply bundle upward, child by child.
/// A child that dies mid-bundle (or was already dead) still accounts
/// for every reply it owed, as synthesized `Reply::Err` frames.
fn pump_children(
    up: &mut TcpStream,
    children: &mut [ChildLink],
    childbuf: &mut Vec<u8>,
    enc: &mut Vec<u8>,
) -> Result<()> {
    for c in children.iter_mut() {
        let expect = c.ranks.len();
        let mut done = 0;
        if let Some(stream) = &mut c.stream {
            while done < expect {
                match wire::read_frame(stream, childbuf) {
                    Ok(Some(_)) => {
                        write_raw(up, childbuf).map_err(|e| {
                            Error::Runtime(format!("worker: relay write: {e}"))
                        })?;
                        done += 1;
                    }
                    _ => break,
                }
            }
            if done < expect {
                c.stream = None;
            }
        }
        for _ in done..expect {
            send_reply(
                up,
                enc,
                &Reply::Err(format!("{RELAY_CHILD_LOST} {} died mid-round", c.rank)),
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Shard;
    use crate::linalg::{DataMatrix, DenseMatrix};
    use std::sync::Arc;

    fn tiny_worker() -> Worker {
        let x = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let shard = Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0]);
        Worker::new(0, shard, Arc::new(crate::loss::Ridge::new(0.1)))
    }

    #[test]
    fn grad_loss_resizes_loaned_buffer() {
        let mut w = tiny_worker();
        let cmd = Command::GradLoss {
            w: Arc::new(vec![0.0, 0.0]),
            out: Vec::new(), // wrong size on purpose
        };
        match execute_command(&mut w, cmd) {
            Reply::VecScalar(g, loss) => {
                assert_eq!(g.len(), 2);
                assert!(loss.is_finite());
            }
            _ => panic!("wrong reply"),
        }
    }

    #[test]
    fn wrong_dimension_payloads_are_error_replies_not_panics() {
        let mut wk = tiny_worker(); // shard dimension 2
        let short = Arc::new(vec![0.0]); // 1 element
        for cmd in [
            Command::GradLoss { w: short.clone(), out: Vec::new() },
            Command::Loss { w: short.clone() },
            Command::DaneSolve {
                w_prev: short.clone(),
                g: Arc::new(vec![0.0, 0.0, 0.0]),
                eta: 1.0,
                mu: 0.0,
                out: Vec::new(),
            },
            Command::Prox { v: vec![0.0; 5], rho: 1.0 },
            Command::ProxAll { targets: vec![vec![0.0; 5]], rho: 1.0 },
        ] {
            match execute_command(&mut wk, cmd) {
                Reply::Err(msg) => {
                    assert!(msg.contains("shard dimension"), "{msg}")
                }
                _ => panic!("wrong-size payload must be rejected"),
            }
        }
        // and the worker still answers well-formed commands afterwards
        let ok = Command::Loss { w: Arc::new(vec![0.0, 0.0]) };
        assert!(matches!(execute_command(&mut wk, ok), Reply::Scalar(_)));
    }

    #[test]
    fn prox_all_picks_own_rank_and_rejects_missing_target() {
        let mut wk = tiny_worker(); // rank 0, d = 2
        let cmd = Command::ProxAll {
            targets: vec![vec![0.1, 0.2], vec![9.0, 9.0]],
            rho: 1.0,
        };
        match execute_command(&mut wk, cmd) {
            Reply::Vec(w) => assert_eq!(w.len(), 2),
            _ => panic!("prox_all must answer with the local prox solution"),
        }
        match execute_command(&mut wk, Command::ProxAll { targets: vec![], rho: 1.0 }) {
            Reply::Err(msg) => assert!(msg.contains("none for worker 0"), "{msg}"),
            _ => panic!("missing target must be an error reply"),
        }
    }

    #[test]
    fn for_envelope_executes_own_rank_and_rejects_misroutes() {
        let mut wk = tiny_worker(); // rank 0
        let inner = Command::Loss { w: Arc::new(vec![0.0, 0.0]) };
        let own = Command::For { rank: 0, inner: Box::new(inner) };
        assert!(matches!(execute_command(&mut wk, own), Reply::Scalar(_)));
        let other = Command::For {
            rank: 3,
            inner: Box::new(Command::Loss { w: Arc::new(vec![0.0, 0.0]) }),
        };
        match execute_command(&mut wk, other) {
            Reply::Err(msg) => assert!(msg.contains("misrouted"), "{msg}"),
            _ => panic!("misrouted For must be rejected"),
        }
    }

    #[test]
    fn init_and_peers_on_running_worker_are_error_replies() {
        let mut w = tiny_worker();
        let p = InitPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            shard: w.shard().clone(),
        };
        match execute_command(&mut w, Command::Init(Box::new(p))) {
            Reply::Err(msg) => assert!(msg.contains("initialized"), "{msg}"),
            _ => panic!("init must not be a compute command"),
        }
        let peers = Command::Peers(Box::new(PeersPayload {
            children: Vec::new(),
            expect_parent: false,
        }));
        match execute_command(&mut w, peers) {
            Reply::Err(msg) => assert!(msg.contains("peers"), "{msg}"),
            _ => panic!("peers must not be a compute command"),
        }
        let by_ref = Command::InitRef(Box::new(InitRefPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            path: "/nonexistent.svm".into(),
            dim: 2,
            n: 2,
            machines: 1,
            shard_seed: 0,
        }));
        match execute_command(&mut w, by_ref) {
            Reply::Err(msg) => assert!(msg.contains("initialized"), "{msg}"),
            _ => panic!("init-ref must not be a compute command"),
        }
    }

    #[test]
    fn build_worker_by_ref_loads_the_shard_this_rank_owns() {
        let dir = crate::util::tempdir::TempDir::new("serve-byref").unwrap();
        let path = dir.path().join("tiny.svm");
        let mut body = String::new();
        for i in 0..10 {
            body.push_str(&format!("{} 1:{}.0 3:0.5\n", if i % 2 == 0 { 1 } else { -1 }, i));
        }
        std::fs::write(&path, &body).unwrap();
        let (n, m, seed) = (10usize, 3usize, 42u64);
        let ds = crate::data::libsvm::load(&path, 3).unwrap();
        let shards = crate::data::shard_dataset(&ds, m, seed);
        for rank in 0..m {
            let wk = build_worker_by_ref(InitRefPayload {
                worker_id: rank,
                loss_name: "ridge".into(),
                lambda: 0.1,
                gram_threads: None,
                path: path.display().to_string(),
                dim: 3,
                n,
                machines: m,
                shard_seed: seed,
            })
            .unwrap();
            assert_eq!(wk.shard().y, shards[rank].y, "rank {rank}");
            // representation-exact compare: no densifying a sparse shard
            // just to check identity (the densify lint's first catch)
            assert_eq!(wk.shard().x, shards[rank].x, "rank {rank}");
        }
        // a missing file is an Err, not a panic
        assert!(build_worker_by_ref(InitRefPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            path: "/nonexistent.svm".into(),
            dim: 3,
            n,
            machines: m,
            shard_seed: seed,
        })
        .is_err());
    }

    #[test]
    fn compressed_grad_loss_matches_uncompressed_compute() {
        use crate::comm::compress::{Codec, CodedVec, CompressedCmd, ReplySpec};
        let mut wk = tiny_worker();
        let point = vec![0.25, -0.5];
        // Uncompressed reference
        let plain = Command::GradLoss { w: Arc::new(point.clone()), out: Vec::new() };
        let (g_ref, loss_ref) = match execute_command(&mut wk, plain) {
            Reply::VecScalar(g, l) => (g, l),
            _ => panic!("wrong reply"),
        };
        // f32 codec, no error feedback: the iterate is f32-representable,
        // so the compute is identical and only the reply is downcast.
        let spec = ReplySpec { codec: Codec::F32, error_feedback: false, seed: 1 };
        let mut rng = crate::util::Rng64::seed_from_u64(0);
        let cmd = Command::CompressedVec(Arc::new(CompressedCmd {
            op: CompressedOp::GradLoss,
            eta: 0.0,
            mu: 0.0,
            spec,
            vecs: vec![CodedVec::encode(Codec::F32, &point, &mut rng)],
        }));
        match execute_command(&mut wk, cmd) {
            Reply::CompressedVec(r) => {
                assert_eq!(r.loss, Some(loss_ref));
                let mut g = Vec::new();
                r.vec.decode_into(&mut g);
                assert_eq!(g.len(), 2);
                for (a, b) in g_ref.iter().zip(g.iter()) {
                    assert_eq!(*a as f32, *b as f32);
                }
            }
            _ => panic!("compressed command must get a compressed reply"),
        }
    }

    #[test]
    fn compressed_wrong_dimension_is_an_error_reply() {
        use crate::comm::compress::{Codec, CodedVec, CompressedCmd, ReplySpec};
        let mut wk = tiny_worker(); // shard dimension 2
        let spec = ReplySpec { codec: Codec::F32, error_feedback: true, seed: 0 };
        let mut rng = crate::util::Rng64::seed_from_u64(0);
        let cmd = Command::CompressedVec(Arc::new(CompressedCmd {
            op: CompressedOp::DaneSolve,
            eta: 1.0,
            mu: 0.0,
            spec,
            vecs: vec![
                CodedVec::encode(Codec::F32, &[0.0; 3], &mut rng),
                CodedVec::encode(Codec::F32, &[0.0; 2], &mut rng),
            ],
        }));
        match execute_command(&mut wk, cmd) {
            Reply::Err(msg) => assert!(msg.contains("shard dimension"), "{msg}"),
            _ => panic!("wrong-size compressed payload must be rejected"),
        }
        // the worker still answers well-formed commands afterwards
        let ok = Command::Loss { w: Arc::new(vec![0.0, 0.0]) };
        assert!(matches!(execute_command(&mut wk, ok), Reply::Scalar(_)));
    }

    #[test]
    fn build_worker_rejects_unknown_loss() {
        let w = tiny_worker();
        let p = InitPayload {
            worker_id: 1,
            loss_name: "bogus".into(),
            lambda: 0.1,
            gram_threads: None,
            shard: w.shard().clone(),
        };
        assert!(build_worker(p).is_err());
    }
}
