//! The worker side of the wire protocol: one command in, one reply out.
//!
//! [`execute_command`] is the single implementation of every collective a
//! worker answers — the threaded engine calls it from its per-worker
//! thread loop and the TCP engine calls it from [`serve_conn`], so the
//! three transports cannot drift apart semantically.
//!
//! [`serve_addr`] is the process entry point behind `dane worker
//! --listen <addr>`: bind, announce the bound address on stdout
//! (`listening on <addr>` — the self-hosted leader parses this line to
//! learn OS-assigned ports), accept one leader connection, answer frames
//! until the leader hangs up. The worker learns everything else — shard,
//! objective, Gram-thread override — from the leader's
//! [`Command::Init`] frame, so a worker process needs no config file.
//!
//! Errors on the compute path become [`Reply::Err`] frames (the leader
//! maps them to `Error::Runtime` and the algorithms to `AlgoError`);
//! only transport failures tear the loop down. Nothing here panics on
//! malformed input.

use crate::comm::wire::{self, Command, InitPayload, Reply};
use crate::config::LossKind;
use crate::loss::make_objective;
use crate::worker::Worker;
use crate::{Error, Result};
use std::io::Write;
use std::net::{TcpListener, TcpStream};

/// Reject a payload vector whose length does not match the shard
/// dimension. A frame can be perfectly well-formed at the codec level
/// and still carry a wrong-size vector (a buggy or hostile leader); the
/// objectives index rows against `w` unchecked in release builds, so
/// this is the line that keeps "malformed input never panics a worker"
/// true end to end.
fn dim_check(what: &str, len: usize, d: usize) -> Option<Reply> {
    if len != d {
        Some(Reply::Err(format!(
            "{what}: payload has {len} elements, shard dimension is {d}"
        )))
    } else {
        None
    }
}

/// Answer one compute command. `Init` is transport setup, not compute —
/// transports that construct their workers directly (threaded) or that
/// handle the handshake themselves (TCP, in [`serve_conn`]) never route
/// it here, so it answers with an error reply.
pub fn execute_command(worker: &mut Worker, cmd: Command) -> Reply {
    let d = worker.dim();
    match cmd {
        Command::Init(_) => {
            Reply::Err("init sent to an already-initialized worker".into())
        }
        Command::GradLoss { w, mut out } => {
            if let Some(err) = dim_check("grad_loss", w.len(), d) {
                return err;
            }
            if out.len() != d {
                out.clear();
                out.resize(d, 0.0);
            }
            match worker.grad(&w, &mut out) {
                Ok(loss) => Reply::VecScalar(out, loss),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Loss { w } => match dim_check("loss", w.len(), d) {
            Some(err) => err,
            None => Reply::Scalar(worker.loss(&w)),
        },
        Command::DaneSolve { w_prev, g, eta, mu, mut out } => {
            if let Some(err) = dim_check("dane_solve w_prev", w_prev.len(), d) {
                return err;
            }
            if let Some(err) = dim_check("dane_solve g", g.len(), d) {
                return err;
            }
            match worker.dane_local_solve_into(&w_prev, &g, eta, mu, &mut out) {
                Ok(()) => Reply::Vec(out),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Prox { v, rho } => {
            if let Some(err) = dim_check("prox", v.len(), d) {
                return err;
            }
            match worker.admm_prox(&v, rho) {
                Ok(w) => Reply::Vec(w),
                Err(e) => Reply::Err(e.to_string()),
            }
        }
        Command::Erm { subsample } => match worker.local_erm() {
            Err(e) => Reply::Err(e.to_string()),
            Ok(full) => match subsample {
                None => Reply::VecPair(full, None),
                Some((r, seed)) => match worker.local_erm_subsample(r, seed) {
                    Ok(sub) => Reply::VecPair(full, Some(sub)),
                    Err(e) => Reply::Err(e.to_string()),
                },
            },
        },
        Command::RowSq => {
            let sh = worker.shard();
            let mut total = 0.0;
            for i in 0..sh.n_effective() {
                total += crate::coordinator::row_sq_norm(sh, i);
            }
            Reply::Scalar(total / sh.n_effective() as f64)
        }
    }
}

/// Build a worker from an [`Command::Init`] payload.
fn build_worker(p: InitPayload) -> Result<Worker> {
    let kind = LossKind::from_name(&p.loss_name)?;
    let obj = make_objective(kind, p.lambda);
    let mut w = Worker::new(p.worker_id, p.shard, obj);
    w.set_gram_threads(p.gram_threads);
    Ok(w)
}

/// `dane worker --listen <addr>`: bind, announce, serve one leader.
pub fn serve_addr(addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Runtime(format!("worker: bind {addr}: {e}")))?;
    let local = listener
        .local_addr()
        .map_err(|e| Error::Runtime(format!("worker: local_addr: {e}")))?;
    // The self-hosted leader reads this exact line to learn the port
    // when the operator (or harness) asked for :0.
    println!("listening on {local}");
    std::io::stdout().flush()?;
    let (stream, _peer) = listener
        .accept()
        .map_err(|e| Error::Runtime(format!("worker: accept: {e}")))?;
    serve_conn(stream)
}

/// Frame loop over an accepted leader connection. Returns `Ok(())` on a
/// clean leader hangup (EOF at a frame boundary), `Err` on transport
/// failure. Compute errors never end the loop — they travel back as
/// [`Reply::Err`] frames.
pub fn serve_conn(stream: TcpStream) -> Result<()> {
    let mut stream = stream;
    stream
        .set_nodelay(true)
        .map_err(|e| Error::Runtime(format!("worker: set_nodelay: {e}")))?;
    let mut frame = Vec::new();
    let mut enc = Vec::new();
    let mut worker: Option<Worker> = None;
    loop {
        match wire::read_frame(&mut stream, &mut frame)? {
            None => return Ok(()), // leader hung up between rounds
            Some(_) => {}
        }
        let reply = match wire::decode_command(&frame) {
            Err(e) => Reply::Err(e.to_string()),
            Ok(Command::Init(p)) => match build_worker(*p) {
                Ok(w) => {
                    worker = Some(w);
                    Reply::Scalar(0.0) // init ack
                }
                Err(e) => Reply::Err(e.to_string()),
            },
            Ok(cmd) => match worker.as_mut() {
                Some(w) => execute_command(w, cmd),
                None => Reply::Err("worker not initialized (no Init frame)".into()),
            },
        };
        wire::encode_reply(&reply, &mut enc)?;
        stream
            .write_all(&enc)
            .map_err(|e| Error::Runtime(format!("worker: reply write: {e}")))?;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Shard;
    use crate::linalg::{DataMatrix, DenseMatrix};
    use std::sync::Arc;

    fn tiny_worker() -> Worker {
        let x = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 2.0]]);
        let shard = Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0]);
        Worker::new(0, shard, Arc::new(crate::loss::Ridge::new(0.1)))
    }

    #[test]
    fn grad_loss_resizes_loaned_buffer() {
        let mut w = tiny_worker();
        let cmd = Command::GradLoss {
            w: Arc::new(vec![0.0, 0.0]),
            out: Vec::new(), // wrong size on purpose
        };
        match execute_command(&mut w, cmd) {
            Reply::VecScalar(g, loss) => {
                assert_eq!(g.len(), 2);
                assert!(loss.is_finite());
            }
            _ => panic!("wrong reply"),
        }
    }

    #[test]
    fn wrong_dimension_payloads_are_error_replies_not_panics() {
        let mut wk = tiny_worker(); // shard dimension 2
        let short = Arc::new(vec![0.0]); // 1 element
        for cmd in [
            Command::GradLoss { w: short.clone(), out: Vec::new() },
            Command::Loss { w: short.clone() },
            Command::DaneSolve {
                w_prev: short.clone(),
                g: Arc::new(vec![0.0, 0.0, 0.0]),
                eta: 1.0,
                mu: 0.0,
                out: Vec::new(),
            },
            Command::Prox { v: vec![0.0; 5], rho: 1.0 },
        ] {
            match execute_command(&mut wk, cmd) {
                Reply::Err(msg) => {
                    assert!(msg.contains("shard dimension"), "{msg}")
                }
                _ => panic!("wrong-size payload must be rejected"),
            }
        }
        // and the worker still answers well-formed commands afterwards
        let ok = Command::Loss { w: Arc::new(vec![0.0, 0.0]) };
        assert!(matches!(execute_command(&mut wk, ok), Reply::Scalar(_)));
    }

    #[test]
    fn init_on_running_worker_is_error_reply() {
        let mut w = tiny_worker();
        let p = InitPayload {
            worker_id: 0,
            loss_name: "ridge".into(),
            lambda: 0.1,
            gram_threads: None,
            shard: w.shard().clone(),
        };
        match execute_command(&mut w, Command::Init(Box::new(p))) {
            Reply::Err(msg) => assert!(msg.contains("initialized"), "{msg}"),
            _ => panic!("init must not be a compute command"),
        }
    }

    #[test]
    fn build_worker_rejects_unknown_loss() {
        let w = tiny_worker();
        let p = InitPayload {
            worker_id: 1,
            loss_name: "bogus".into(),
            lambda: 0.1,
            gram_threads: None,
            shard: w.shard().clone(),
        };
        assert!(build_worker(p).is_err());
    }
}
