//! Cached quadratic local solver.
//!
//! For quadratic objectives the shard Hessian `H_i = (1/n) X^T X + lam I`
//! is constant, so DANE's local system `(H_i + mu I) delta = g` (and
//! ADMM's `(H_i + rho I) w = b`) can be served by factoring
//! `G + shift I` (G the scaled Gram matrix) **once per shift** and
//! back-substituting every round: O(d^2) per round instead of O(d^3) or a
//! CG sweep. This is the main native hot-path optimization measured in
//! EXPERIMENTS.md §Perf.

use crate::data::Shard;
use crate::linalg::{CholeskyFactor, DenseMatrix};
use crate::Result;
use std::collections::HashMap;

/// Above this dimension the dense Gram (d x d) is not worth materializing
/// and workers fall back to Hessian-free CG. At d = 1024: the Gram is
/// 8 MiB, and each memoized Cholesky factor stores L *and* L^T (for
/// contiguous forward/backward solves), i.e. 16 MiB per cached shift —
/// DANE uses one shift, ADMM a second, so budget up to ~40 MiB per
/// worker at the cap.
pub const CHOLESKY_MAX_DIM: usize = 1024;

/// Thread count for the one-time Gram build: the deterministic parallel
/// kernel pays off only on genuinely large shards, and a fixed
/// size-ladder keeps the count (hence the reduction order and the bits)
/// reproducible for a given machine. Below the cutoff the serial tiled
/// kernel runs — which also keeps every small-fixture test bit-identical
/// to `DenseMatrix::gram`.
fn gram_build_threads(rows: usize, cols: usize) -> usize {
    const PAR_GRAM_MIN_CELLS: usize = 1 << 18; // 256k cells ~ 2 MiB of X
    if rows.saturating_mul(cols) < PAR_GRAM_MIN_CELLS {
        1
    } else {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(4)
    }
}

/// Gram matrix + per-shift Cholesky factors + X^T y of one shard.
pub struct QuadCache {
    /// (1/n) X^T X — *without* the lambda ridge; shifts are applied on top.
    gram: DenseMatrix,
    /// (1/n) X^T y.
    xty: Vec<f64>,
    /// shift (f64 bits) -> factor of (gram + shift I).
    factors: HashMap<u64, CholeskyFactor>,
}

impl QuadCache {
    pub fn build(shard: &Shard) -> Result<Self> {
        Self::build_with_threads(shard, None)
    }

    /// [`QuadCache::build`] with an explicit Gram-build thread count
    /// (config `threads`): for dense shards `Some(t)` bypasses the
    /// size ladder and runs `par_gram(t)` regardless of shard size —
    /// the knob that makes the deterministic parallel kernel reachable
    /// from `dane run`. **Sparse shards are refused**: building a dense
    /// d x d Gram of a sparse dataset is exactly the densification the
    /// matrix-free path exists to avoid, and `Worker::quad_usable`
    /// never routes them here — an `Err` (not a silent densify) keeps
    /// any future caller honest.
    pub fn build_with_threads(shard: &Shard, threads: Option<usize>) -> Result<Self> {
        let n = shard.n_effective() as f64;
        // Dense shards large enough to amortize thread spawns build the
        // Gram with the deterministic parallel kernel.
        let mut gram = match &shard.x {
            crate::linalg::DataMatrix::Dense(x) => {
                let t = threads
                    .unwrap_or_else(|| gram_build_threads(x.rows(), x.cols()));
                x.par_gram(t)
            }
            crate::linalg::DataMatrix::Sparse(x) => {
                return Err(crate::Error::Config(format!(
                    "QuadCache: refusing to densify a {}x{} sparse shard \
                     (matrix-free Newton-CG handles sparse local solves)",
                    x.rows(),
                    x.cols()
                )));
            }
        };
        for i in 0..gram.rows() {
            for j in 0..gram.cols() {
                let v = gram.get(i, j) / n;
                gram.set(i, j, v);
            }
        }
        let mut xty = vec![0.0; shard.d()];
        shard.x.rmatvec(&shard.y, &mut xty)?;
        for v in xty.iter_mut() {
            *v /= n;
        }
        Ok(QuadCache { gram, xty, factors: HashMap::new() })
    }

    /// (1/n) X^T y — the constant linear term of the quadratic.
    pub fn xty(&self) -> &[f64] {
        &self.xty
    }

    /// The scaled Gram matrix (1/n) X^T X.
    pub fn gram(&self) -> &DenseMatrix {
        &self.gram
    }

    /// Solve (gram + shift I) x = rhs, factoring on first use of `shift`.
    ///
    /// `shift` must make the system SPD (shift > 0, or the Gram already
    /// full-rank). Factors are memoized: DANE reuses one shift for the
    /// whole run, ADMM a second.
    pub fn solve_shifted(&mut self, shift: f64, rhs: &[f64]) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        self.solve_shifted_into(shift, rhs, &mut out)?;
        Ok(out)
    }

    /// [`QuadCache::solve_shifted`] into a caller-owned buffer: after the
    /// one-time factorization, steady-state solves are pure O(d^2)
    /// back-substitution with zero heap allocations — the worker half of
    /// the zero-allocation round protocol (EXPERIMENTS.md §Perf).
    pub fn solve_shifted_into(
        &mut self,
        shift: f64,
        rhs: &[f64],
        out: &mut Vec<f64>,
    ) -> Result<()> {
        let key = shift.to_bits();
        if !self.factors.contains_key(&key) {
            let shifted = self.gram.add_diag(shift);
            self.factors.insert(key, CholeskyFactor::factor(&shifted)?);
        }
        out.clear();
        out.extend_from_slice(rhs);
        self.factors[&key].solve_in_place(out);
        Ok(())
    }

    /// Number of distinct factored shifts (diagnostics / tests).
    pub fn cached_factor_count(&self) -> usize {
        self.factors.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Shard;
    use crate::linalg::{ops, DataMatrix, DenseMatrix};

    fn shard() -> Shard {
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0, 0.0],
            vec![0.0, 1.0, -1.0],
            vec![2.0, 0.0, 1.0],
            vec![1.0, 1.0, 1.0],
        ]);
        Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0, 0.5, 2.0])
    }

    #[test]
    fn solve_matches_direct() {
        let s = shard();
        let mut cache = QuadCache::build(&s).unwrap();
        let rhs = vec![1.0, 0.0, -1.0];
        let x = cache.solve_shifted(0.3, &rhs).unwrap();
        // residual check against (gram + 0.3 I) x = rhs
        let shifted = cache.gram().add_diag(0.3);
        let mut ax = vec![0.0; 3];
        shifted.matvec(&x, &mut ax);
        let mut r = vec![0.0; 3];
        ops::sub(&ax, &rhs, &mut r);
        assert!(ops::norm2(&r) < 1e-10);
    }

    #[test]
    fn explicit_thread_count_matches_serial_build() {
        // The `threads` config override must not change the math: the
        // parallel Gram agrees with the serial one to reduction-order
        // rounding, and t = 1 is bit-identical by the par_gram contract.
        let s = shard();
        let serial = QuadCache::build(&s).unwrap();
        let one = QuadCache::build_with_threads(&s, Some(1)).unwrap();
        assert_eq!(one.gram().data(), serial.gram().data());
        let par = QuadCache::build_with_threads(&s, Some(3)).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let (a, b) = (par.gram().get(i, j), serial.gram().get(i, j));
                assert!((a - b).abs() <= 1e-12 * a.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn sparse_shard_is_refused_not_densified() {
        let x = crate::linalg::CsrMatrix::from_triplets(
            3,
            4,
            &[(0, 1, 2.0), (1, 0, 5.0), (2, 3, 4.0)],
        );
        let s = Shard::new(DataMatrix::Sparse(x), vec![1.0, -1.0, 0.5]);
        let err = QuadCache::build(&s).unwrap_err();
        assert!(err.to_string().contains("sparse"), "{err}");
    }

    #[test]
    fn factors_are_memoized() {
        let s = shard();
        let mut cache = QuadCache::build(&s).unwrap();
        let rhs = vec![1.0, 1.0, 1.0];
        cache.solve_shifted(0.5, &rhs).unwrap();
        cache.solve_shifted(0.5, &rhs).unwrap();
        assert_eq!(cache.cached_factor_count(), 1);
        cache.solve_shifted(0.7, &rhs).unwrap();
        assert_eq!(cache.cached_factor_count(), 2);
    }

    #[test]
    fn solve_into_matches_and_reuses_buffer() {
        let s = shard();
        let mut cache = QuadCache::build(&s).unwrap();
        let rhs = vec![1.0, 0.0, -1.0];
        let direct = cache.solve_shifted(0.3, &rhs).unwrap();
        let mut buf = Vec::new();
        cache.solve_shifted_into(0.3, &rhs, &mut buf).unwrap();
        assert_eq!(buf, direct);
        let cap = buf.capacity();
        cache.solve_shifted_into(0.3, &rhs, &mut buf).unwrap();
        assert_eq!(buf, direct);
        assert_eq!(buf.capacity(), cap, "steady-state solve must not reallocate");
    }

    #[test]
    fn xty_is_scaled() {
        let s = shard();
        let cache = QuadCache::build(&s).unwrap();
        let mut expect = vec![0.0; 3];
        s.x.rmatvec(&s.y, &mut expect).unwrap();
        ops::scale(0.25, &mut expect);
        assert_eq!(cache.xty(), &expect[..]);
    }

    #[test]
    fn respects_padding_scaling() {
        // padded shard: n_effective = 4, two zero rows appended
        let x = DenseMatrix::from_rows(&[
            vec![1.0, 2.0, 0.0],
            vec![0.0, 1.0, -1.0],
            vec![2.0, 0.0, 1.0],
            vec![1.0, 1.0, 1.0],
            vec![0.0, 0.0, 0.0],
            vec![0.0, 0.0, 0.0],
        ]);
        let padded = Shard::with_padding(
            DataMatrix::Dense(x),
            vec![1.0, -1.0, 0.5, 2.0, 0.0, 0.0],
            4,
        );
        let c1 = QuadCache::build(&shard()).unwrap();
        let c2 = QuadCache::build(&padded).unwrap();
        assert_eq!(c1.xty(), c2.xty());
        assert_eq!(c1.gram().data(), c2.gram().data());
    }
}
