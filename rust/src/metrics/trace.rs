//! Per-round convergence records.

use crate::comm::CommStats;

/// One communication round's snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRow {
    /// Round index (0 = initial point, before any communication).
    pub round: usize,
    /// phi(w) at the current iterate.
    pub objective: f64,
    /// phi(w) - phi(w_hat), when a reference value is known.
    pub suboptimality: Option<f64>,
    /// ||grad phi(w)||.
    pub grad_norm: Option<f64>,
    /// Test-set loss (fig. 4), when evaluated.
    pub test_loss: Option<f64>,
    /// Cumulative communication rounds consumed by the *algorithm*.
    pub comm_rounds: u64,
    /// Cumulative bytes.
    pub comm_bytes: u64,
    /// Cumulative modeled network seconds.
    pub comm_modeled_seconds: f64,
    /// Wallclock seconds since the run started.
    pub elapsed_seconds: f64,
    /// Cumulative bytes *measured on the socket* (TCP engine; exactly 0
    /// on in-memory engines). Sits next to the modeled `comm_bytes` so
    /// figures can plot convergence against real bytes moved.
    pub wire_bytes: u64,
    /// What `wire_bytes` would have been with every compressed round
    /// frame carrying its raw f64 payload (see
    /// `CommStats::payload_bytes_raw`). Equal to `wire_bytes` under
    /// `codec: none` and 0 on in-memory engines; the gap between the
    /// two columns is the measured savings of the active codec.
    pub payload_bytes_raw: u64,
    /// One-time bring-up bytes measured on the socket (Init/InitRef +
    /// Peers and their acks; 0 on in-memory engines). Constant across a
    /// run's rows; O(n·d) for by-value Init, O(m) for `--data-by-ref`.
    pub startup_bytes: u64,
    /// Workers alive (answering collectives) when the row was recorded.
    /// Equals `machines` on fault-free runs and under `respawn`; drops
    /// when a `degrade` policy quarantines dead ranks.
    pub alive_workers: u64,
    /// Cumulative successful fault recoveries (respawns/redials or
    /// quorum degradations) up to this row. 0 on fault-free runs.
    pub recoveries: u64,
}

/// A full run's trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub rows: Vec<TraceRow>,
}

impl Trace {
    pub fn new() -> Self {
        Trace { rows: Vec::new() }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn push(
        &mut self,
        round: usize,
        objective: f64,
        suboptimality: Option<f64>,
        grad_norm: Option<f64>,
        test_loss: Option<f64>,
        comm: &CommStats,
        elapsed_seconds: f64,
    ) {
        self.rows.push(TraceRow {
            round,
            objective,
            suboptimality,
            grad_norm,
            test_loss,
            comm_rounds: comm.rounds,
            comm_bytes: comm.bytes,
            comm_modeled_seconds: comm.modeled_seconds,
            elapsed_seconds,
            wire_bytes: comm.wire_bytes,
            payload_bytes_raw: comm.payload_bytes_raw,
            startup_bytes: comm.startup_bytes,
            alive_workers: comm.alive_workers,
            recoveries: comm.recoveries,
        });
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Suboptimality series (None entries skipped).
    pub fn suboptimality(&self) -> Vec<f64> {
        self.rows.iter().filter_map(|r| r.suboptimality).collect()
    }

    pub fn last_suboptimality(&self) -> Option<f64> {
        self.rows.iter().rev().find_map(|r| r.suboptimality)
    }

    pub fn last_objective(&self) -> Option<f64> {
        self.rows.last().map(|r| r.objective)
    }

    /// First round index whose suboptimality is below `tol`
    /// (the paper's fig. 3 "iterations to reach < 1e-6" metric).
    pub fn rounds_to_tol(&self, tol: f64) -> Option<usize> {
        self.rows
            .iter()
            .find(|r| r.suboptimality.map(|s| s < tol).unwrap_or(false))
            .map(|r| r.round)
    }

    /// Per-round linear contraction factors of the suboptimality
    /// (Theorem-2 diagnostics): ratio of consecutive suboptimalities.
    pub fn contraction_factors(&self) -> Vec<f64> {
        let s = self.suboptimality();
        s.windows(2)
            .filter(|w| w[0] > 0.0 && w[1] >= 0.0)
            .map(|w| w[1] / w[0])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        let mut t = Trace::new();
        let mut comm = CommStats::default();
        for (i, s) in [1.0, 0.1, 0.01, 1e-7].iter().enumerate() {
            comm.rounds = i as u64;
            t.push(i, 5.0 + s, Some(*s), Some(s.sqrt()), None, &comm, 0.1 * i as f64);
        }
        t
    }

    #[test]
    fn rounds_to_tol_finds_first_crossing() {
        let t = sample();
        assert_eq!(t.rounds_to_tol(1e-6), Some(3));
        assert_eq!(t.rounds_to_tol(0.5), Some(1));
        assert_eq!(t.rounds_to_tol(1e-12), None);
    }

    #[test]
    fn contraction_factors_are_ratios() {
        let t = sample();
        let f = t.contraction_factors();
        assert!((f[0] - 0.1).abs() < 1e-12);
        assert!((f[1] - 0.1).abs() < 1e-12);
    }

    #[test]
    fn last_accessors() {
        let t = sample();
        assert_eq!(t.last_suboptimality(), Some(1e-7));
        assert_eq!(t.len(), 4);
        assert!(!t.is_empty());
        assert!(Trace::new().last_suboptimality().is_none());
    }
}
