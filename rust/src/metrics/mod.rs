//! Convergence traces and emission (CSV / JSON).
//!
//! Every algorithm run produces a [`Trace`]: one row per communication
//! round with the objective value, suboptimality against the reference
//! ERM, gradient norm, optional test loss, cumulative communication
//! stats and wallclock. The bench harnesses turn traces into exactly the
//! rows/series the paper's figures report.

pub mod emit;
pub mod trace;

pub use trace::{Trace, TraceRow};
