//! Trace emission: CSV for plotting, JSON summaries for EXPERIMENTS.md.

use super::trace::Trace;
use crate::util::Json;
use crate::Result;
use std::io::Write;

/// CSV header matching [`super::TraceRow`] field order. The
/// run-specific columns: `elapsed_seconds` (col 9, wallclock — the one
/// column excluded from bit-exact comparisons), `wire_bytes` (col 10,
/// measured socket bytes, 0 off the TCP engine), `payload_bytes_raw`
/// (col 11, what col 10 would be without the active codec — equal to it
/// under `codec: none`, 0 off the TCP engine), `startup_bytes` (col
/// 12, one-time bring-up bytes, 0 off the TCP engine), `alive_workers`
/// (col 13) and `recoveries` (col 14, both fault-policy observability;
/// `machines` resp. 0 on fault-free runs).
pub const CSV_HEADER: &str = "round,objective,suboptimality,grad_norm,test_loss,comm_rounds,comm_bytes,comm_modeled_seconds,elapsed_seconds,wire_bytes,payload_bytes_raw,startup_bytes,alive_workers,recoveries";

/// Write a trace as CSV.
pub fn write_csv<W: Write>(trace: &Trace, w: W) -> Result<()> {
    write_csv_impl(trace, w, None)
}

/// Write a trace as CSV with a `# truncated: <cause>` trailer line —
/// the artifact a failed run leaves behind so partial progress is never
/// lost (the `#` prefix keeps naive CSV readers from choking).
pub fn write_csv_truncated<W: Write>(trace: &Trace, w: W, cause: &str) -> Result<()> {
    write_csv_impl(trace, w, Some(cause))
}

fn write_csv_impl<W: Write>(trace: &Trace, mut w: W, truncated: Option<&str>) -> Result<()> {
    writeln!(w, "{CSV_HEADER}")?;
    for r in &trace.rows {
        writeln!(
            w,
            "{},{:.17e},{},{},{},{},{},{:.6e},{:.6},{},{},{},{},{}",
            r.round,
            r.objective,
            opt(r.suboptimality),
            opt(r.grad_norm),
            opt(r.test_loss),
            r.comm_rounds,
            r.comm_bytes,
            r.comm_modeled_seconds,
            r.elapsed_seconds,
            r.wire_bytes,
            r.payload_bytes_raw,
            r.startup_bytes,
            r.alive_workers,
            r.recoveries,
        )?;
    }
    if let Some(cause) = truncated {
        // keep the trailer single-line whatever the cause contains
        writeln!(w, "# truncated: {}", cause.replace('\n', " "))?;
    }
    Ok(())
}

fn opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.17e}")).unwrap_or_default()
}

/// Write a trace CSV to a file path, creating parent dirs.
pub fn write_csv_file(trace: &Trace, path: &std::path::Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)?;
    write_csv(trace, std::io::BufWriter::new(f))
}

/// [`write_csv_file`] for a run that died mid-way: the partial trace
/// plus a `# truncated: <cause>` trailer.
pub fn write_csv_file_truncated(
    trace: &Trace,
    path: &std::path::Path,
    cause: &str,
) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)?;
    write_csv_truncated(trace, std::io::BufWriter::new(f), cause)
}

/// Compact JSON summary of a run (EXPERIMENTS.md fodder).
pub fn summary_json(name: &str, trace: &Trace) -> Json {
    let last = trace.rows.last();
    let num_or_null = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("name", Json::str(name)),
        ("rounds", Json::num(trace.len().saturating_sub(1) as f64)),
        ("final_objective", num_or_null(last.map(|r| r.objective))),
        ("final_suboptimality", num_or_null(trace.last_suboptimality())),
        ("comm_rounds", num_or_null(last.map(|r| r.comm_rounds as f64))),
        ("comm_bytes", num_or_null(last.map(|r| r.comm_bytes as f64))),
        ("wire_bytes", num_or_null(last.map(|r| r.wire_bytes as f64))),
        (
            "payload_bytes_raw",
            num_or_null(last.map(|r| r.payload_bytes_raw as f64)),
        ),
        (
            "startup_bytes",
            num_or_null(last.map(|r| r.startup_bytes as f64)),
        ),
        (
            "comm_modeled_seconds",
            num_or_null(last.map(|r| r.comm_modeled_seconds)),
        ),
        ("elapsed_seconds", num_or_null(last.map(|r| r.elapsed_seconds))),
        (
            "alive_workers",
            num_or_null(last.map(|r| r.alive_workers as f64)),
        ),
        ("recoveries", num_or_null(last.map(|r| r.recoveries as f64))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommStats;

    fn sample() -> Trace {
        let mut t = Trace::new();
        let comm = CommStats {
            rounds: 2,
            bytes: 128,
            modeled_seconds: 1e-3,
            wire_bytes: 96,
            payload_bytes_raw: 192,
            startup_bytes: 4096,
            alive_workers: 4,
            recoveries: 1,
        };
        t.push(0, 1.5, Some(0.5), None, Some(0.7), &comm, 0.01);
        t
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut buf = Vec::new();
        write_csv(&sample(), &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        let mut lines = s.lines();
        assert_eq!(lines.next().unwrap(), CSV_HEADER);
        let row = lines.next().unwrap();
        assert!(row.starts_with("0,"));
        assert!(row.contains(",128,"));
        // empty optional renders as empty field
        assert!(row.contains(",,"));
    }

    #[test]
    fn summary_shape() {
        let j = summary_json("t", &sample());
        assert_eq!(j.get("name").unwrap().as_str(), Some("t"));
        assert_eq!(j.get("comm_bytes").unwrap().as_f64(), Some(128.0));
        assert_eq!(j.get("wire_bytes").unwrap().as_f64(), Some(96.0));
        assert_eq!(j.get("payload_bytes_raw").unwrap().as_f64(), Some(192.0));
        assert_eq!(j.get("startup_bytes").unwrap().as_f64(), Some(4096.0));
        let s = j.get("final_suboptimality").unwrap().as_f64().unwrap();
        assert!((s - 0.5).abs() < 1e-15);
    }

    #[test]
    fn fault_columns_and_truncation_trailer() {
        let mut buf = Vec::new();
        write_csv(&sample(), &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        let row = s.lines().nth(1).unwrap();
        assert!(row.ends_with(",4,1"), "alive/recoveries trail the row: {row}");

        let mut buf = Vec::new();
        write_csv_truncated(&sample(), &mut buf, "worker lost: tcp: worker 2")
            .unwrap();
        let s = String::from_utf8(buf).unwrap();
        let last = s.lines().last().unwrap();
        assert_eq!(last, "# truncated: worker lost: tcp: worker 2");
    }

    #[test]
    fn csv_file_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new("emit").unwrap();
        let path = dir.path().join("sub/t.csv");
        write_csv_file(&sample(), &path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with(CSV_HEADER));
    }
}
