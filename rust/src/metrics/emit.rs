//! Trace emission: CSV for plotting, JSON summaries for EXPERIMENTS.md.

use super::trace::Trace;
use crate::util::Json;
use crate::Result;
use std::io::Write;

/// CSV header matching [`super::TraceRow`] field order. The
/// run-specific columns sit last: `elapsed_seconds` (wallclock),
/// `wire_bytes` (measured socket bytes, 0 off the TCP engine) and
/// `startup_bytes` (one-time bring-up bytes, 0 off the TCP engine) —
/// so cross-engine trace comparison is "all columns but the last
/// three" (`cut -d, -f1-8`).
pub const CSV_HEADER: &str = "round,objective,suboptimality,grad_norm,test_loss,comm_rounds,comm_bytes,comm_modeled_seconds,elapsed_seconds,wire_bytes,startup_bytes";

/// Write a trace as CSV.
pub fn write_csv<W: Write>(trace: &Trace, mut w: W) -> Result<()> {
    writeln!(w, "{CSV_HEADER}")?;
    for r in &trace.rows {
        writeln!(
            w,
            "{},{:.17e},{},{},{},{},{},{:.6e},{:.6},{},{}",
            r.round,
            r.objective,
            opt(r.suboptimality),
            opt(r.grad_norm),
            opt(r.test_loss),
            r.comm_rounds,
            r.comm_bytes,
            r.comm_modeled_seconds,
            r.elapsed_seconds,
            r.wire_bytes,
            r.startup_bytes,
        )?;
    }
    Ok(())
}

fn opt(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.17e}")).unwrap_or_default()
}

/// Write a trace CSV to a file path, creating parent dirs.
pub fn write_csv_file(trace: &Trace, path: &std::path::Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)?;
    write_csv(trace, std::io::BufWriter::new(f))
}

/// Compact JSON summary of a run (EXPERIMENTS.md fodder).
pub fn summary_json(name: &str, trace: &Trace) -> Json {
    let last = trace.rows.last();
    let num_or_null = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
    Json::obj(vec![
        ("name", Json::str(name)),
        ("rounds", Json::num(trace.len().saturating_sub(1) as f64)),
        ("final_objective", num_or_null(last.map(|r| r.objective))),
        ("final_suboptimality", num_or_null(trace.last_suboptimality())),
        ("comm_rounds", num_or_null(last.map(|r| r.comm_rounds as f64))),
        ("comm_bytes", num_or_null(last.map(|r| r.comm_bytes as f64))),
        ("wire_bytes", num_or_null(last.map(|r| r.wire_bytes as f64))),
        (
            "startup_bytes",
            num_or_null(last.map(|r| r.startup_bytes as f64)),
        ),
        (
            "comm_modeled_seconds",
            num_or_null(last.map(|r| r.comm_modeled_seconds)),
        ),
        ("elapsed_seconds", num_or_null(last.map(|r| r.elapsed_seconds))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::CommStats;

    fn sample() -> Trace {
        let mut t = Trace::new();
        let comm = CommStats {
            rounds: 2,
            bytes: 128,
            modeled_seconds: 1e-3,
            wire_bytes: 96,
            startup_bytes: 4096,
        };
        t.push(0, 1.5, Some(0.5), None, Some(0.7), &comm, 0.01);
        t
    }

    #[test]
    fn csv_has_header_and_rows() {
        let mut buf = Vec::new();
        write_csv(&sample(), &mut buf).unwrap();
        let s = String::from_utf8(buf).unwrap();
        let mut lines = s.lines();
        assert_eq!(lines.next().unwrap(), CSV_HEADER);
        let row = lines.next().unwrap();
        assert!(row.starts_with("0,"));
        assert!(row.contains(",128,"));
        // empty optional renders as empty field
        assert!(row.contains(",,"));
    }

    #[test]
    fn summary_shape() {
        let j = summary_json("t", &sample());
        assert_eq!(j.get("name").unwrap().as_str(), Some("t"));
        assert_eq!(j.get("comm_bytes").unwrap().as_f64(), Some(128.0));
        assert_eq!(j.get("wire_bytes").unwrap().as_f64(), Some(96.0));
        assert_eq!(j.get("startup_bytes").unwrap().as_f64(), Some(4096.0));
        let s = j.get("final_suboptimality").unwrap().as_f64().unwrap();
        assert!((s - 0.5).abs() < 1e-15);
    }

    #[test]
    fn csv_file_roundtrip() {
        let dir = crate::util::tempdir::TempDir::new("emit").unwrap();
        let path = dir.path().join("sub/t.csv");
        write_csv_file(&sample(), &path).unwrap();
        let s = std::fs::read_to_string(&path).unwrap();
        assert!(s.starts_with(CSV_HEADER));
    }
}
