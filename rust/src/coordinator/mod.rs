//! The leader: synchronous round engine + the paper's algorithm and every
//! baseline it compares against.
//!
//! Algorithms are written against the [`Cluster`] abstraction, which
//! exposes exactly the collective operations a real deployment would
//! have, with every *algorithmic* communication round accounted (the
//! `eval_*` methods are instrumentation — free, as a separate monitoring
//! plane would be — so baselines aren't charged for the measurements the
//! figures need).
//!
//! Round accounting follows the paper: DANE = 2 averages/iteration
//! (gradient, iterate), GD/ADMM/L-BFGS = 1, OSA = 1 total (footnote 5).

pub mod admm;
pub mod checkpoint;
pub mod dane;
pub mod driver;
pub mod fault;
pub mod gd;
pub mod lbfgs;
pub mod osa;
pub mod tcp;
pub mod threaded;

use crate::comm::{Collective, CommStats, NetModel};
use crate::data::{shard_dataset, Dataset, Shard};
use crate::linalg::ops;
use crate::loss::Objective;
use crate::metrics::Trace;
use crate::runtime::{ArtifactRegistry, PjrtSession};
use crate::worker::{Worker, WorkerBackend};
use crate::Result;
use std::sync::Arc;

/// The collective surface the algorithms run on.
pub trait Cluster {
    /// Number of machines m.
    fn m(&self) -> usize;
    /// Parameter dimension d.
    fn dim(&self) -> usize;
    fn objective(&self) -> Arc<dyn Objective>;

    /// Averaged gradient and objective at w — ONE allreduce (gradient and
    /// loss share the round, as they would share a payload).
    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)>;

    /// [`Cluster::grad_and_loss`] written into a caller-owned buffer, so
    /// steady-state driver loops can run allocation-free. Engines
    /// override this as the primitive; the default delegates (and pays
    /// the allocation) for exotic implementations.
    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let (gv, loss) = self.grad_and_loss(w)?;
        g.copy_from_slice(&gv);
        Ok(loss)
    }

    /// Averaged objective only — ONE allreduce (line-search probes).
    fn loss_only(&mut self, w: &[f64]) -> Result<f64>;

    /// DANE inner step: every worker solves its local problem (paper
    /// eq. 13) given the averaged gradient, results averaged — ONE
    /// allreduce.
    fn dane_round(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64)
        -> Result<Vec<f64>>;

    /// [`Cluster::dane_round`] written into a caller-owned buffer
    /// (`out` must not alias `w_prev`/`g`); same override contract as
    /// [`Cluster::grad_and_loss_into`].
    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        let w = self.dane_round(w_prev, g, eta, mu)?;
        out.copy_from_slice(&w);
        Ok(())
    }

    /// Theorem-5 variant of the inner step: only machine 1 solves, and
    /// w^(t) = w_1^(t). Still one (broadcast) round — the solution must
    /// reach every machine.
    fn dane_round_first(&mut self, w_prev: &[f64], g: &[f64], eta: f64, mu: f64)
        -> Result<Vec<f64>>;

    /// ADMM proximal solves on per-worker targets — local compute, no
    /// communication (the averaging is a separate explicit round). Slot
    /// k is `None` exactly when rank k is quarantined under a `degrade`
    /// fault policy; fault-free engines return all-`Some`.
    fn prox_all(&mut self, targets: &[Vec<f64>], rho: f64)
        -> Result<Vec<Option<Vec<f64>>>>;

    /// Per-worker ERMs (optionally each worker also solves a subsampled
    /// ERM for bias correction) — local compute, no communication.
    /// `None` slots mark quarantined ranks, as in [`Cluster::prox_all`].
    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)>;

    /// Average per-worker vectors — ONE allreduce. The reduction itself
    /// is leader-local (the inputs are already in hand), but the round
    /// it accounts for is a real collective, and exotic engines may
    /// fail it — `Result` keeps the whole trait on the PR-3 error
    /// contract (no collective method panics on a dead cluster).
    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>>;

    /// Mean squared row norm of the data, for smoothness upper bounds —
    /// ONE allreduce (computed once, then cached). Worker death
    /// propagates as an error like every other round.
    fn avg_row_sq_norm(&mut self) -> Result<f64>;

    /// Instrumentation (uncounted): objective at w.
    fn eval_loss(&mut self, w: &[f64]) -> Result<f64>;
    /// Instrumentation (uncounted): gradient + objective at w.
    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)>;

    fn comm_stats(&self) -> CommStats;
    fn reset_comm(&mut self);

    /// Workers currently answering collectives. Equals [`Cluster::m`]
    /// fault-free; drops below it when a `degrade` policy quarantines
    /// dead ranks.
    fn alive(&self) -> usize {
        self.m()
    }

    /// Recover from worker loss: quarantine dead ranks and — when
    /// `respawn` is set and the engine can — bring replacements back up
    /// and re-initialize them. Returns the number of alive workers
    /// afterwards. Engines that cannot recover keep the default.
    fn recover(&mut self, _respawn: bool) -> Result<usize> {
        Err(crate::Error::Runtime(
            "this cluster engine cannot recover workers".into(),
        ))
    }

    /// Overwrite cumulative communication stats (checkpoint resume picks
    /// up the crashed run's accounting). No-op where unsupported.
    fn restore_comm(&mut self, _stats: &CommStats) {}

    /// Chaos hook: forcibly kill worker `rank` (test/CI fault
    /// injection). No-op on engines without killable workers.
    fn fault_kill_worker(&mut self, _rank: usize) {}

    /// Arm [`Cluster::recover`] with everything a rebuild needs (the
    /// source dataset and the sharding seed). Called by the driver
    /// before a supervised run; no-op on engines that either cannot
    /// recover or (like TCP) retain their init payloads unconditionally.
    fn enable_recovery(
        &mut self,
        _ds: &Dataset,
        _shard_seed: u64,
        _gram_threads: Option<usize>,
    ) {
    }
}

/// Shared run parameters + instrumentation context.
#[derive(Clone)]
pub struct RunCtx {
    /// Maximum algorithm iterations (communication-round iterations).
    pub max_rounds: usize,
    /// Stop when suboptimality < tol (requires `phi_star`).
    pub tol: f64,
    /// Reference optimum phi(w_hat) from [`crate::solver::erm_solve`].
    pub phi_star: Option<f64>,
    /// Evaluate test loss each round (fig. 4).
    pub test_shard: Option<Shard>,
    /// Periodic checkpoint spec (and, on `--resume`, the restored
    /// state). `None` = no checkpointing — the fault-free common case.
    pub ckpt: Option<Arc<checkpoint::CkptSpec>>,
}

impl RunCtx {
    pub fn new(max_rounds: usize) -> Self {
        RunCtx {
            max_rounds,
            tol: 1e-6,
            phi_star: None,
            test_shard: None,
            ckpt: None,
        }
    }

    pub fn with_reference(mut self, phi_star: f64) -> Self {
        self.phi_star = Some(phi_star);
        self
    }

    pub fn with_tol(mut self, tol: f64) -> Self {
        self.tol = tol;
        self
    }

    pub fn with_test_shard(mut self, shard: Shard) -> Self {
        self.test_shard = Some(shard);
        self
    }

    pub fn with_checkpoint(mut self, spec: Arc<checkpoint::CkptSpec>) -> Self {
        self.ckpt = Some(spec);
        self
    }

    pub(crate) fn subopt(&self, objective: f64) -> Option<f64> {
        self.phi_star.map(|s| objective - s)
    }

    pub(crate) fn test_loss(
        &self,
        obj: &dyn Objective,
        w: &[f64],
    ) -> Option<f64> {
        self.test_shard.as_ref().map(|sh| {
            let mut rowbuf = vec![0.0; sh.n()];
            obj.value(sh, w, &mut rowbuf)
        })
    }
}

/// Result of one algorithm run.
#[derive(Debug, Clone)]
pub struct AlgoResult {
    pub name: String,
    pub w: Vec<f64>,
    pub trace: Trace,
    pub converged: bool,
}

/// A failed algorithm run: the underlying cluster error plus everything
/// the run had recorded when it died — the trace-so-far and the last
/// accepted iterate — so a partial run can still be post-mortemed.
///
/// Worker death (or any cluster round failure) surfaces through this
/// type from every algorithm: no `.expect()`/panic anywhere on the
/// cluster-call path. `From<Box<AlgoError>> for crate::Error` lets `?`
/// flatten it into the crate error at the driver/CLI boundary.
#[derive(Debug)]
pub struct AlgoError {
    /// Which algorithm failed ("dane", "gd", ...).
    pub algo: &'static str,
    /// The cluster/numerical error that killed the run.
    pub error: crate::Error,
    /// Iterate at the time of failure.
    pub w: Vec<f64>,
    /// Trace rows recorded before the failing round.
    pub trace: Trace,
}

impl std::fmt::Display for AlgoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} failed after {} recorded rounds: {}",
            self.algo,
            self.trace.len(),
            self.error
        )
    }
}

impl std::error::Error for AlgoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.error)
    }
}

impl From<Box<AlgoError>> for crate::Error {
    fn from(e: Box<AlgoError>) -> Self {
        // Carry the whole payload (iterate + partial trace) instead of
        // flattening to a string: the CLI writes the partial CSV from
        // it. `Display` output is unchanged.
        crate::Error::Algo(e)
    }
}

/// What every algorithm run returns: the finished result, or the failure
/// with the partial trace attached (boxed — the payload is large).
pub type AlgoOutcome = std::result::Result<AlgoResult, Box<AlgoError>>;

/// Assemble an [`AlgoOutcome`] from an algorithm's inner-loop result and
/// the state it accumulated (shared tail of all `run` functions).
pub(crate) fn finish(
    algo: &'static str,
    res: Result<()>,
    w: Vec<f64>,
    trace: Trace,
    converged: bool,
) -> AlgoOutcome {
    match res {
        Ok(()) => Ok(AlgoResult { name: algo.into(), w, trace, converged }),
        Err(error) => Err(Box::new(AlgoError { algo, error, w, trace })),
    }
}

/// In-process cluster: m workers driven sequentially by the leader.
///
/// Deterministic (fixed iteration order) and single-threaded — the right
/// engine for tests and benches, where we measure *rounds*, not threads.
/// Gradient/loss averages are n_i-weighted so that uneven shards still
/// produce the exact global phi (shard sizes differ by at most one row).
pub struct SerialCluster {
    workers: Vec<Worker>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
    /// n_i / N weights.
    weights: Vec<f64>,
    /// cached mean squared row norm
    row_sq: Option<f64>,
    /// round-persistent scratch: one worker gradient / local solution at
    /// a time, so steady-state rounds allocate nothing
    gi_buf: Vec<f64>,
    wi_buf: Vec<f64>,
}

impl SerialCluster {
    /// Shard `ds` over m workers with the native backend and a free
    /// network model.
    pub fn new(ds: &Dataset, obj: Arc<dyn Objective>, m: usize, seed: u64) -> Self {
        Self::with_net(ds, obj, m, seed, NetModel::free())
    }

    pub fn with_net(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
    ) -> Self {
        let shards = shard_dataset(ds, m, seed);
        Self::from_shards(shards, obj, net)
    }

    /// Build from pre-made shards (tests, padding experiments).
    pub fn from_shards(
        shards: Vec<Shard>,
        obj: Arc<dyn Objective>,
        net: NetModel,
    ) -> Self {
        assert!(!shards.is_empty());
        let d = shards[0].d();
        let total: usize = shards.iter().map(|s| s.n_effective()).sum();
        let weights: Vec<f64> = shards
            .iter()
            .map(|s| s.n_effective() as f64 / total as f64)
            .collect();
        let workers = shards
            .into_iter()
            .enumerate()
            .map(|(i, s)| Worker::new(i, s, obj.clone()))
            .collect();
        SerialCluster {
            workers,
            obj,
            comm: Collective::new(net),
            d,
            weights,
            row_sq: None,
            gi_buf: vec![0.0; d],
            wi_buf: vec![0.0; d],
        }
    }

    /// Switch every worker to the PJRT backend over `registry`.
    pub fn use_pjrt(&mut self, registry: Arc<ArtifactRegistry>) -> Result<()> {
        for w in &mut self.workers {
            let session =
                PjrtSession::for_shard(registry.clone(), w.shard(), self.obj.as_ref())?;
            w.set_backend(WorkerBackend::Pjrt(Arc::new(session)));
        }
        Ok(())
    }

    pub fn workers_mut(&mut self) -> &mut [Worker] {
        &mut self.workers
    }

    /// Override every worker's Gram-build thread count (config
    /// `threads`). Takes effect on caches built after the call.
    pub fn set_gram_threads(&mut self, threads: Option<usize>) {
        for w in &mut self.workers {
            w.set_gram_threads(threads);
        }
    }

    pub fn workers(&self) -> &[Worker] {
        &self.workers
    }

    /// Weighted (exact) gradient+loss average into `g`, shared by the
    /// counted and uncounted paths. Accumulation is n_i-weighted in rank
    /// order — the reduction the threaded engine must reproduce
    /// bit-exactly (smoke_cluster_parity).
    fn gather_grad_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        g.fill(0.0);
        let gi = &mut self.gi_buf;
        let mut loss = 0.0;
        for (k, worker) in self.workers.iter_mut().enumerate() {
            let li = worker.grad(w, gi)?;
            ops::axpy(self.weights[k], gi, g);
            loss += self.weights[k] * li;
        }
        Ok(loss)
    }

    fn gather_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.gather_grad_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn gather_loss(&mut self, w: &[f64]) -> f64 {
        self.workers
            .iter_mut()
            .enumerate()
            .map(|(k, worker)| self.weights[k] * worker.loss(w))
            .sum()
    }
}

impl Cluster for SerialCluster {
    fn m(&self) -> usize {
        self.workers.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.grad_and_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let loss = self.gather_grad_loss_into(w, g)?;
        // one allreduce round: d-vector + scalar per worker
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(loss)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let loss = self.gather_loss(w);
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; self.d];
        self.dane_round_into(w_prev, g, eta, mu, &mut acc)?;
        Ok(acc)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        out.fill(0.0);
        let inv_m = 1.0 / self.workers.len() as f64;
        let wi = &mut self.wi_buf;
        for worker in &mut self.workers {
            worker.dane_local_solve_into(w_prev, g, eta, mu, wi)?;
            // paper step (*): unweighted average of local solutions
            ops::axpy(inv_m, wi, out);
        }
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(())
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let w1 = self.workers[0].dane_local_solve(w_prev, g, eta, mu)?;
        let m = self.m();
        self.comm.count_round(m, self.d); // broadcast of w_1
        Ok(w1)
    }

    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> Result<Vec<Option<Vec<f64>>>> {
        assert_eq!(targets.len(), self.m());
        self.workers
            .iter_mut()
            .zip(targets)
            .map(|(w, v)| w.admm_prox(v, rho).map(Some))
            .collect()
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        let mut full = Vec::with_capacity(self.m());
        for w in &mut self.workers {
            full.push(Some(w.local_erm()?));
        }
        let sub = match subsample {
            None => None,
            Some((r, seed)) => {
                let mut out = Vec::with_capacity(self.m());
                for w in &mut self.workers {
                    out.push(Some(w.local_erm_subsample(r, seed)?));
                }
                Some(out)
            }
        };
        Ok((full, sub))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        Ok(out)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        if let Some(v) = self.row_sq {
            return Ok(v);
        }
        let mut total = 0.0;
        let mut rows = 0usize;
        for w in &self.workers {
            let sh = w.shard();
            for i in 0..sh.n_effective() {
                // squared row norm via row_dot against itself is not
                // available generically; compute through matvec-free path
                total += row_sq_norm(sh, i);
            }
            rows += sh.n_effective();
        }
        let v = total / rows as f64;
        let m = self.m();
        self.comm.count_round(m, 1);
        self.row_sq = Some(v);
        Ok(v)
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        Ok(self.gather_loss(w))
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.gather_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        let mut s = self.comm.stats().clone();
        s.alive_workers = self.workers.len() as u64;
        s
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
    }

    fn restore_comm(&mut self, stats: &CommStats) {
        self.comm.restore(stats);
    }
}

/// Spawn an OS thread or die trying. This is the one place the
/// concurrent engines are allowed to abort: thread creation fails only
/// when the OS is out of resources at cluster bring-up (before any
/// round has run), there is no round state to unwind, and returning a
/// half-wired cluster would be worse than stopping. Every other panic
/// on the coordinator/comm/worker surface is a `dane-lint` error.
pub(crate) fn must_spawn<F, T>(builder: std::thread::Builder, f: F) -> std::thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    // lint:allow(panic-freedom): OS thread exhaustion at bring-up has no recovery path; documented above
    builder.spawn(f).unwrap_or_else(|e| panic!("spawn thread: {e}"))
}

pub(crate) fn row_sq_norm(shard: &Shard, i: usize) -> f64 {
    match &shard.x {
        crate::linalg::DataMatrix::Dense(m) => {
            let r = m.row(i);
            ops::dot(r, r)
        }
        crate::linalg::DataMatrix::Sparse(s) => {
            let (_, vals) = s.row(i);
            ops::dot(vals, vals)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::linalg::{DataMatrix, DenseMatrix};
    use crate::loss::Ridge;

    fn tiny_dataset(n: usize, d: usize, seed: u64) -> Dataset {
        crate::data::synthetic_fig2(n, d, 0.005, seed)
    }

    #[test]
    fn grad_matches_single_shard() {
        let ds = tiny_dataset(64, 6, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 4, 7);
        let w = vec![0.2; 6];
        let (g, loss) = cluster.grad_and_loss(&w).unwrap();

        let all = ds.as_single_shard();
        let mut g_ref = vec![0.0; 6];
        let mut rb = vec![0.0; 64];
        let loss_ref = obj.value_grad(&all, &w, &mut g_ref, &mut rb);
        for j in 0..6 {
            assert!((g[j] - g_ref[j]).abs() < 1e-12, "{} vs {}", g[j], g_ref[j]);
        }
        assert!((loss - loss_ref).abs() < 1e-12);
        assert_eq!(cluster.comm_stats().rounds, 1);
    }

    #[test]
    fn uneven_shards_still_exact() {
        // 65 rows over 4 workers: shard sizes 17,16,16,16
        let ds = tiny_dataset(65, 5, 9);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.02));
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 4, 1);
        let w = vec![-0.1; 5];
        let (_, loss) = cluster.grad_and_loss(&w).unwrap();
        let all = ds.as_single_shard();
        let mut rb = vec![0.0; 65];
        assert!((loss - obj.value(&all, &w, &mut rb)).abs() < 1e-12);
    }

    #[test]
    fn eval_paths_are_uncounted() {
        let ds = tiny_dataset(32, 4, 5);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 2, 2);
        cluster.eval_loss(&[0.0; 4]).unwrap();
        cluster.eval_grad_loss(&[0.0; 4]).unwrap();
        assert_eq!(cluster.comm_stats().rounds, 0);
        cluster.loss_only(&[0.0; 4]).unwrap();
        assert_eq!(cluster.comm_stats().rounds, 1);
    }

    #[test]
    fn allreduce_mean_vecs_counts() {
        let ds = tiny_dataset(32, 4, 5);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 2, 2);
        let out = cluster.allreduce_mean_vecs(&[vec![1.0; 4], vec![3.0; 4]]).unwrap();
        assert_eq!(out, vec![2.0; 4]);
        assert_eq!(cluster.comm_stats().rounds, 1);
    }

    #[test]
    fn from_shards_respects_dims() {
        let x = DenseMatrix::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let s = Shard::new(DataMatrix::Dense(x), vec![1.0, -1.0]);
        let cluster = SerialCluster::from_shards(
            vec![s],
            Arc::new(Ridge::new(0.0)),
            NetModel::free(),
        );
        assert_eq!(cluster.m(), 1);
        assert_eq!(cluster.dim(), 2);
    }
}
