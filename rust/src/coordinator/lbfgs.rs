//! Distributed L-BFGS — the gradient-based quasi-Newton reference
//! (Agarwal et al. 2011 run L-BFGS in exactly this pattern: allreduce the
//! gradient, apply the two-loop recursion at every node).
//!
//! Communication: one allreduce per gradient, plus one allreduce per
//! line-search probe (a distributed function evaluation is a real round —
//! we charge it, unlike the uncounted instrumentation plane). Like all
//! gradient-span methods it is subject to the eq. (8) lower bound; the
//! benches show it cannot match DANE's n-dependent rate.

use super::{finish, AlgoOutcome, Cluster, RunCtx};
use crate::linalg::ops;
use crate::metrics::Trace;
use crate::Result;
use std::collections::VecDeque;

/// L-BFGS options.
#[derive(Debug, Clone, Copy)]
pub struct LbfgsOptions {
    /// History size (pairs kept).
    pub history: usize,
    /// Max line-search probes per iteration.
    pub max_probes: usize,
    /// Armijo constant.
    pub armijo_c: f64,
}

impl Default for LbfgsOptions {
    fn default() -> Self {
        LbfgsOptions { history: 10, max_probes: 20, armijo_c: 1e-4 }
    }
}

/// Two-loop recursion: r = H_k g using the (s, y) history.
fn two_loop(
    g: &[f64],
    hist: &VecDeque<(Vec<f64>, Vec<f64>, f64)>, // (s, y, 1/(y^T s))
) -> Vec<f64> {
    let mut q = g.to_vec();
    let mut alphas = Vec::with_capacity(hist.len());
    for (s, y, rho) in hist.iter().rev() {
        let alpha = rho * ops::dot(s, &q);
        ops::axpy(-alpha, y, &mut q);
        alphas.push(alpha);
    }
    // Initial scaling gamma = s^T y / y^T y of the newest pair.
    if let Some((s, y, _)) = hist.back() {
        let gamma = ops::dot(s, y) / ops::dot(y, y).max(1e-300);
        ops::scale(gamma, &mut q);
    }
    for ((s, y, rho), alpha) in hist.iter().zip(alphas.into_iter().rev()) {
        let beta = rho * ops::dot(y, &q);
        ops::axpy(alpha - beta, s, &mut q);
    }
    q
}

/// Run distributed L-BFGS from w = 0. Cluster failures return as an
/// error carrying the trace-so-far — never a panic.
pub fn run(cluster: &mut dyn Cluster, opts: &LbfgsOptions, ctx: &RunCtx) -> AlgoOutcome {
    let mut w = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = run_loop(cluster, opts, ctx, &mut w, &mut trace, &mut converged);
    finish("lbfgs", res, w, trace, converged)
}

fn run_loop(
    cluster: &mut dyn Cluster,
    opts: &LbfgsOptions,
    ctx: &RunCtx,
    w: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let d = cluster.dim();
    let obj = cluster.objective();
    let mut hist: VecDeque<(Vec<f64>, Vec<f64>, f64)> = VecDeque::new();
    let t0 = std::time::Instant::now();

    let mut start = 0;
    let (mut g, mut loss);
    if let Some(c) = ctx.ckpt.as_ref().and_then(|ck| ck.resume_for("lbfgs")) {
        let restore = |name: &str| -> Result<Vec<f64>> {
            Ok(c.vec(name)
                .ok_or_else(|| crate::Error::Runtime(format!("checkpoint lacks {name}")))?
                .to_vec())
        };
        *w = restore("w")?;
        g = restore("g")?;
        loss = c
            .scalar("loss")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks loss".into()))?;
        // Curvature pairs s{i}/y{i}/rho{i}, oldest first, as saved.
        let mut i = 0;
        while let (Some(s), Some(y), Some(rho)) = (
            c.vec(&format!("s{i}")),
            c.vec(&format!("y{i}")),
            c.scalar(&format!("rho{i}")),
        ) {
            hist.push_back((s.to_vec(), y.to_vec(), rho));
            i += 1;
        }
        *trace = c.trace.clone();
        cluster.restore_comm(&c.comm);
        start = c.round as usize + 1;
    } else {
        let (g0, loss0) = cluster.grad_and_loss(w)?;
        g = g0;
        loss = loss0;
    }
    for iter in start..=ctx.max_rounds {
        let subopt = ctx.subopt(loss);
        trace.push(
            iter,
            loss,
            subopt,
            Some(ops::norm2(&g)),
            ctx.test_loss(obj.as_ref(), w),
            &cluster.comm_stats(),
            t0.elapsed().as_secs_f64(),
        );
        if subopt.map(|s| s < ctx.tol).unwrap_or(false) || ops::norm2(&g) < 1e-14 {
            *converged = true;
            break;
        }
        if iter == ctx.max_rounds {
            break;
        }

        let dir = two_loop(&g, &hist);
        let slope = ops::dot(&g, &dir);
        // Fallback to steepest descent if the direction degenerated.
        let (dir, slope) = if slope <= 0.0 { (g.clone(), ops::dot(&g, &g)) } else { (dir, slope) };

        // Backtracking line search; every probe is a counted round.
        let mut step = 1.0;
        let mut accepted = false;
        let mut w_try = vec![0.0; d];
        for _ in 0..opts.max_probes {
            for j in 0..d {
                w_try[j] = w[j] - step * dir[j];
            }
            let f_try = cluster.loss_only(&w_try)?;
            if f_try <= loss - opts.armijo_c * step * slope {
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            // numerical floor; stop
            break;
        }

        let (g_new, loss_new) = cluster.grad_and_loss(&w_try)?;
        // Curvature pair.
        let mut s = vec![0.0; d];
        let mut y = vec![0.0; d];
        for j in 0..d {
            s[j] = w_try[j] - w[j];
            y[j] = g_new[j] - g[j];
        }
        let ys = ops::dot(&y, &s);
        if ys > 1e-12 * ops::norm2(&y) * ops::norm2(&s) {
            if hist.len() == opts.history {
                hist.pop_front();
            }
            hist.push_back((s, y, 1.0 / ys));
        }
        *w = w_try;
        g = g_new;
        loss = loss_new;

        if let Some(ck) = &ctx.ckpt {
            let names: Vec<(String, String, String)> = (0..hist.len())
                .map(|i| (format!("s{i}"), format!("y{i}"), format!("rho{i}")))
                .collect();
            let mut scalars: Vec<(&str, f64)> = vec![("loss", loss)];
            let mut vecs: Vec<(&str, &[f64])> = vec![("w", w.as_slice()), ("g", g.as_slice())];
            for ((sn, yn, rn), (s, y, rho)) in names.iter().zip(&hist) {
                vecs.push((sn, s.as_slice()));
                vecs.push((yn, y.as_slice()));
                scalars.push((rn, *rho));
            }
            ck.maybe_save("lbfgs", iter, &cluster.comm_stats(), &scalars, &vecs, trace)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::{Objective, Ridge, SmoothHinge};
    use crate::solver::erm_solve;
    use std::sync::Arc;

    #[test]
    fn converges_on_quadratic() {
        let ds = synthetic_fig2(1024, 12, 0.005, 2);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 4, 3);
        let ctx = RunCtx::new(100).with_reference(phi_star).with_tol(1e-8);
        let res = run(&mut cluster, &LbfgsOptions::default(), &ctx).unwrap();
        assert!(res.converged, "last {:?}", res.trace.last_suboptimality());
    }

    #[test]
    fn converges_on_hinge() {
        let ds = crate::data::covtype_like(512, 32, 31);
        let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(1e-3));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 4, 7);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-6);
        let res = run(&mut cluster, &LbfgsOptions::default(), &ctx).unwrap();
        assert!(res.converged, "last {:?}", res.trace.last_suboptimality());
    }

    #[test]
    fn line_search_probes_are_charged() {
        let ds = synthetic_fig2(256, 6, 0.005, 4);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 2, 2);
        let ctx = RunCtx::new(3).with_tol(0.0);
        let res = run(&mut cluster, &LbfgsOptions::default(), &ctx).unwrap();
        let last = res.trace.rows.last().unwrap();
        // At minimum: 1 initial grad + per iteration (>=1 probe + 1 grad).
        assert!(last.comm_rounds >= 1 + 3 * 2, "{}", last.comm_rounds);
    }

    #[test]
    fn two_loop_identity_without_history() {
        let hist = VecDeque::new();
        let g = vec![1.0, -2.0, 3.0];
        assert_eq!(two_loop(&g, &hist), g);
    }
}
