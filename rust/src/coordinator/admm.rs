//! Global-consensus ADMM (Boyd et al. 2011, §7) — the paper's main
//! multi-round comparator.
//!
//! Scaled form over `min (1/m) sum_i phi_i(w_i)  s.t.  w_i = z`:
//!
//! ```text
//! w_i^{k+1} = argmin_w phi_i(w) + (rho/2)||w - (z^k - u_i^k)||^2   (local)
//! z^{k+1}   = mean_i (w_i^{k+1} + u_i^k)                            (1 round)
//! u_i^{k+1} = u_i^k + w_i^{k+1} - z^{k+1}                           (local)
//! ```
//!
//! One distributed average per iteration (paper footnote 5). Unlike DANE,
//! the update ignores the statistical similarity of the phi_i — the
//! fig. 2/3 benches show exactly the consequence: its rate does not
//! improve with the per-machine sample size.

use super::{finish, AlgoOutcome, Cluster, RunCtx};
use crate::linalg::ops;
use crate::metrics::Trace;
use crate::Result;

/// ADMM hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct AdmmOptions {
    /// Augmented-Lagrangian penalty rho.
    pub rho: f64,
}

impl Default for AdmmOptions {
    fn default() -> Self {
        AdmmOptions { rho: 1.0 }
    }
}

/// Run consensus ADMM from z = 0. Cluster failures return as an error
/// carrying the trace-so-far — never a panic.
pub fn run(cluster: &mut dyn Cluster, opts: &AdmmOptions, ctx: &RunCtx) -> AlgoOutcome {
    let mut z = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = run_loop(cluster, opts, ctx, &mut z, &mut trace, &mut converged);
    finish("admm", res, z, trace, converged)
}

fn run_loop(
    cluster: &mut dyn Cluster,
    opts: &AdmmOptions,
    ctx: &RunCtx,
    z: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let d = cluster.dim();
    let m = cluster.m();
    let obj = cluster.objective();
    let mut u: Vec<Vec<f64>> = vec![vec![0.0; d]; m];
    let u_names: Vec<String> = (0..m).map(|i| format!("u{i}")).collect();
    let t0 = std::time::Instant::now();

    let mut start = 1;
    if let Some(c) = ctx.ckpt.as_ref().and_then(|ck| ck.resume_for("admm")) {
        *z = c
            .vec("z")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks consensus z".into()))?
            .to_vec();
        for (ui, name) in u.iter_mut().zip(&u_names) {
            *ui = c
                .vec(name)
                .ok_or_else(|| crate::Error::Runtime(format!("checkpoint lacks dual {name}")))?
                .to_vec();
        }
        *trace = c.trace.clone();
        cluster.restore_comm(&c.comm);
        start = c.round as usize + 1;
    } else {
        // round 0: initial point (instrumentation only)
        let loss0 = cluster.eval_loss(z)?;
        trace.push(
            0,
            loss0,
            ctx.subopt(loss0),
            None,
            ctx.test_loss(obj.as_ref(), z),
            &cluster.comm_stats(),
            0.0,
        );
    }

    for iter in start..=ctx.max_rounds {
        // Local proximal solves at v_i = z - u_i.
        let targets: Vec<Vec<f64>> = u
            .iter()
            .map(|ui| {
                let mut v = z.clone();
                ops::axpy(-1.0, ui, &mut v);
                v
            })
            .collect();
        let w_all = cluster.prox_all(&targets, opts.rho)?;

        // Consensus average (the iteration's single communication round).
        // Under a degraded quorum only the surviving ranks contribute —
        // the mean is over |alive| slots; a quarantined rank's dual is
        // frozen with its shard out of the consensus.
        let sums: Vec<Vec<f64>> = w_all
            .iter()
            .zip(&u)
            .filter_map(|(wi, ui)| {
                wi.as_ref().map(|wi| {
                    let mut s = wi.clone();
                    ops::axpy(1.0, ui, &mut s);
                    s
                })
            })
            .collect();
        *z = cluster.allreduce_mean_vecs(&sums)?;

        // Dual updates (survivors only).
        for (ui, wi) in u.iter_mut().zip(&w_all) {
            if let Some(wi) = wi {
                for j in 0..d {
                    ui[j] += wi[j] - z[j];
                }
            }
        }

        // Instrumentation.
        let loss = cluster.eval_loss(z)?;
        let subopt = ctx.subopt(loss);
        trace.push(
            iter,
            loss,
            subopt,
            None,
            ctx.test_loss(obj.as_ref(), z),
            &cluster.comm_stats(),
            t0.elapsed().as_secs_f64(),
        );
        if subopt.map(|s| s < ctx.tol).unwrap_or(false) {
            *converged = true;
            break;
        }
        if let Some(ck) = &ctx.ckpt {
            let mut vecs: Vec<(&str, &[f64])> = Vec::with_capacity(m + 1);
            vecs.push(("z", z.as_slice()));
            for (name, ui) in u_names.iter().zip(&u) {
                vecs.push((name, ui.as_slice()));
            }
            ck.maybe_save("admm", iter, &cluster.comm_stats(), &[], &vecs, trace)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::{Objective, Ridge, SmoothHinge};
    use crate::solver::erm_solve;
    use std::sync::Arc;

    #[test]
    fn admm_converges_on_quadratic() {
        let ds = synthetic_fig2(1024, 10, 0.005, 3);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 4, 5);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-6);
        let res = run(&mut cluster, &AdmmOptions { rho: 0.1 }, &ctx).unwrap();
        assert!(res.converged, "last: {:?}", res.trace.last_suboptimality());
    }

    #[test]
    fn admm_converges_on_hinge() {
        let ds = crate::data::covtype_like(512, 64, 21);
        let lam = 1e-3;
        let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 4, 9);
        let ctx = RunCtx::new(300).with_reference(phi_star).with_tol(1e-6);
        let res = run(&mut cluster, &AdmmOptions { rho: 0.05 }, &ctx).unwrap();
        assert!(res.converged, "last: {:?}", res.trace.last_suboptimality());
    }

    #[test]
    fn one_round_per_iteration() {
        let ds = synthetic_fig2(256, 6, 0.005, 4);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 4, 4);
        let ctx = RunCtx::new(7).with_tol(0.0);
        let res = run(&mut cluster, &AdmmOptions { rho: 0.1 }, &ctx).unwrap();
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 7);
    }

    #[test]
    fn single_machine_admm_fast() {
        // m=1: consensus is immediate; prox iterations converge quickly.
        let ds = synthetic_fig2(256, 6, 0.005, 8);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 1, 4);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-8);
        let res = run(&mut cluster, &AdmmOptions { rho: 0.05 }, &ctx).unwrap();
        assert!(res.converged);
    }
}
