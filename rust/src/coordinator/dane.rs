//! DANE — the paper's method (fig. 1).
//!
//! Per iteration:
//! 1. allreduce the local gradients -> global gradient at w^(t-1)  (round 1)
//! 2. every machine solves its local perturbed problem (eq. 13)
//! 3. allreduce the local solutions -> w^(t)                        (round 2)
//!
//! For quadratic objectives the iterate follows the closed form of
//! eq. (16); Theorem 2 gives contraction factor `||I - eta H~^{-1} H||_2`,
//! which *improves with n* in the stochastic setting (Theorem 3) — the
//! fig. 2 bench regenerates exactly that behavior.

use super::{finish, AlgoOutcome, Cluster, RunCtx};
use crate::linalg::ops;
use crate::metrics::Trace;
use crate::Result;

/// How the local solutions combine into w^(t).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Combine {
    /// Paper step (*): w^(t) = (1/m) sum_i w_i^(t).
    #[default]
    Average,
    /// The Theorem-5 variant: w^(t) = w_1^(t) (machine 1's solution).
    /// Its linear rate depends on how well D_{h_1} tracks D_phi; with
    /// similar shards it matches Average, with dissimilar ones it is
    /// noisier — `first_vs_average` tests pin both behaviors.
    First,
}

/// DANE hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct DaneOptions {
    /// Learning rate eta (paper experiments: 1).
    pub eta: f64,
    /// Proximal regularizer mu (paper experiments: 0, lambda, 3 lambda).
    pub mu: f64,
    /// Stop early when ||grad|| falls below this (safety net when no
    /// reference optimum is available).
    pub grad_tol: f64,
    /// Iterate combination rule (paper step (*) vs Theorem 5).
    pub combine: Combine,
}

impl Default for DaneOptions {
    fn default() -> Self {
        DaneOptions { eta: 1.0, mu: 0.0, grad_tol: 1e-13, combine: Combine::Average }
    }
}

/// Run DANE from w = 0.
///
/// The steady-state loop is allocation-free on the leader: the iterate
/// double-buffers through `w`/`w_next` and the gradient lands in a
/// persistent buffer via the `*_into` collective methods (the trace rows
/// themselves are instrumentation and amortize their own storage).
///
/// A failed cluster round (worker death, singular local solve, ...)
/// aborts the run and returns the error with the trace-so-far attached —
/// it never panics.
pub fn run(cluster: &mut dyn Cluster, opts: &DaneOptions, ctx: &RunCtx) -> AlgoOutcome {
    let mut w = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = run_loop(cluster, opts, ctx, &mut w, &mut trace, &mut converged);
    finish("dane", res, w, trace, converged)
}

fn run_loop(
    cluster: &mut dyn Cluster,
    opts: &DaneOptions,
    ctx: &RunCtx,
    w: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let d = cluster.dim();
    let obj = cluster.objective();
    let mut w_next = vec![0.0; d];
    let mut g = vec![0.0; d];
    let t0 = std::time::Instant::now();

    let mut start = 0;
    if let Some(c) = ctx.ckpt.as_ref().and_then(|ck| ck.resume_for("dane")) {
        *w = c
            .vec("w")
            .ok_or_else(|| crate::Error::Runtime("checkpoint lacks iterate w".into()))?
            .to_vec();
        *trace = c.trace.clone();
        cluster.restore_comm(&c.comm);
        start = c.round as usize + 1;
    }

    for iter in start..=ctx.max_rounds {
        // Gradient round (also yields the objective for the trace). The
        // final pass is instrumentation only — the algorithm is done.
        let loss = if iter < ctx.max_rounds && !*converged {
            cluster.grad_and_loss_into(w, &mut g)?
        } else {
            let (gv, l) = cluster.eval_grad_loss(w)?;
            g.copy_from_slice(&gv);
            l
        };

        let subopt = ctx.subopt(loss);
        trace.push(
            iter,
            loss,
            subopt,
            Some(ops::norm2(&g)),
            ctx.test_loss(obj.as_ref(), w),
            &cluster.comm_stats(),
            t0.elapsed().as_secs_f64(),
        );

        if let Some(s) = subopt {
            if s < ctx.tol {
                *converged = true;
                break;
            }
        }
        if ops::norm2(&g) < opts.grad_tol {
            *converged = true;
            break;
        }
        if iter == ctx.max_rounds {
            break;
        }

        // Local-solve + combine round.
        match opts.combine {
            Combine::Average => {
                cluster.dane_round_into(w, &g, opts.eta, opts.mu, &mut w_next)?;
                std::mem::swap(w, &mut w_next);
            }
            Combine::First => {
                *w = cluster.dane_round_first(w, &g, opts.eta, opts.mu)?;
            }
        }

        if let Some(ck) = &ctx.ckpt {
            ck.maybe_save(
                "dane",
                iter,
                &cluster.comm_stats(),
                &[],
                &[("w", w.as_slice())],
                trace,
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::{Objective, Ridge, SmoothHinge};
    use crate::solver::erm_solve;
    use std::sync::Arc;

    #[test]
    fn single_machine_quadratic_one_step() {
        // m=1, mu=0, eta=1 on a quadratic: DANE is an exact Newton step.
        let ds = synthetic_fig2(128, 8, 0.005, 1);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 1, 1);
        let ctx = RunCtx::new(5).with_reference(phi_star).with_tol(1e-10);
        let res = run(&mut cluster, &DaneOptions::default(), &ctx).unwrap();
        assert!(res.converged);
        assert_eq!(res.trace.rounds_to_tol(1e-10), Some(1), "one Newton step");
    }

    #[test]
    fn multi_machine_quadratic_linear_rate() {
        let ds = synthetic_fig2(4096, 16, 0.005, 2);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 8, 3);
        let ctx = RunCtx::new(30).with_reference(phi_star).with_tol(1e-10);
        let res = run(&mut cluster, &DaneOptions::default(), &ctx).unwrap();
        assert!(res.converged, "subopt trace: {:?}", res.trace.suboptimality());
        // contraction factors should be < 1 (linear convergence)
        let f = res.trace.contraction_factors();
        assert!(!f.is_empty());
        assert!(f.iter().take(3).all(|&r| r < 0.9), "{f:?}");
    }

    #[test]
    fn rate_improves_with_n() {
        // Theorem 3: fixed m, growing N -> faster convergence. With 8x
        // the data the predicted contraction factor shrinks by
        // ~sqrt(8) ~ 2.8x (Thm 3: rate = O(sqrt(d~/n)) w.h.p.), so the
        // purely directional assertion below has that whole factor as
        // slack against seed-to-seed noise. The geometric mean over the
        // early rounds (before the suboptimality nears the f64 noise
        // floor) is the stable per-round rate estimator.
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let geo_rate = |f: &[f64]| {
            let k = f.len().min(5).max(1);
            let prod: f64 = f.iter().take(k).product();
            prod.powf(1.0 / k as f64)
        };
        let mut rates = Vec::new();
        for &n in &[512usize, 4096] {
            let ds = synthetic_fig2(n, 16, 0.005, 7);
            let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
            let mut cluster = SerialCluster::new(&ds, obj.clone(), 4, 5);
            let ctx = RunCtx::new(25).with_reference(phi_star).with_tol(1e-12);
            let res = run(&mut cluster, &DaneOptions::default(), &ctx).unwrap();
            let f = res.trace.contraction_factors();
            assert!(!f.is_empty(), "n={n}: no contraction factors");
            rates.push(geo_rate(&f));
        }
        assert!(
            rates[1] < rates[0],
            "contraction should improve with n: {rates:?}"
        );
    }

    #[test]
    fn first_vs_average_combination() {
        // Theorem-5 variant: with large similar shards, taking machine
        // 1's solution instead of the average still converges linearly.
        let ds = synthetic_fig2(8192, 12, 0.005, 6);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj.clone(), 4, 9);
        let ctx = RunCtx::new(40).with_reference(phi_star).with_tol(1e-9);
        let opts = DaneOptions { combine: Combine::First, ..Default::default() };
        let res_first = run(&mut cluster, &opts, &ctx).unwrap();
        assert!(res_first.converged, "{:?}", res_first.trace.suboptimality());

        // ...but the averaged variant contracts at least as fast
        // (variance reduction across machines). The advantage is an
        // in-expectation statement (Thm 2 vs Thm 5 constants); on a
        // single seed the measured rates carry shard-sampling noise, so
        // allow a 2x cushion rather than asserting strict dominance.
        let mut cluster = SerialCluster::new(&ds, obj, 4, 9);
        let res_avg = run(&mut cluster, &DaneOptions::default(), &ctx).unwrap();
        assert!(res_avg.converged, "{:?}", res_avg.trace.suboptimality());
        let rate = |t: &crate::metrics::Trace| {
            let f = t.contraction_factors();
            let k = f.len().min(4).max(1);
            f.iter().take(k).sum::<f64>() / k as f64
        };
        assert!(rate(&res_avg.trace) <= rate(&res_first.trace) * 2.0);
    }

    #[test]
    fn dane_counts_two_rounds_per_iteration() {
        let ds = synthetic_fig2(256, 6, 0.005, 4);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 4, 4);
        let ctx = RunCtx::new(5).with_tol(0.0); // never converges on tol
        let res = run(&mut cluster, &DaneOptions::default(), &ctx).unwrap();
        // 5 full iterations = 5 grad rounds + 5 iterate rounds
        let last = res.trace.rows.last().unwrap();
        assert_eq!(last.comm_rounds, 10);
    }

    #[test]
    fn hinge_converges_with_mu() {
        // Per-machine n must be large enough for H_i ~ H (the paper's
        // own caveat: DANE may not converge when shards are tiny).
        let ds = crate::data::covtype_like(4096, 64, 11);
        let lam = 1e-2;
        let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 4, 13);
        let ctx = RunCtx::new(40).with_reference(phi_star).with_tol(1e-6);
        let opts = DaneOptions { eta: 1.0, mu: 3.0 * lam, ..Default::default() };
        let res = run(&mut cluster, &opts, &ctx).unwrap();
        assert!(res.converged, "trace: {:?}", res.trace.suboptimality());
    }

    #[test]
    fn tiny_shards_may_oscillate_but_mu_stabilizes() {
        // The failure mode fig. 3 marks with "*": small n + small mu can
        // stall above tol. A large mu (gradient-descent-like regime) must
        // still make monotone progress.
        let ds = crate::data::covtype_like(512, 64, 17);
        let lam = 1e-3;
        let obj: Arc<dyn Objective> = Arc::new(SmoothHinge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 8, 13);
        let ctx = RunCtx::new(15).with_reference(phi_star).with_tol(0.0);
        let opts = DaneOptions { eta: 1.0, mu: 1.0, ..Default::default() };
        let res = run(&mut cluster, &opts, &ctx).unwrap();
        let s = res.trace.suboptimality();
        assert!(
            s.last().unwrap() < &(s[0] * 0.9),
            "large-mu DANE should still descend: {s:?}"
        );
    }
}
