//! Threaded message-passing cluster engine.
//!
//! Where [`super::SerialCluster`] drives workers inline (deterministic,
//! the measurement engine for every figure), `ThreadedCluster` runs each
//! worker on its own OS thread behind a command/reply protocol — the
//! actual leader/worker process topology a deployment would have, minus
//! the sockets. Messages are the typed [`crate::comm::wire`]
//! `Command`/`Reply` enums — the same protocol `TcpCluster` moves over
//! real sockets, here passed by value through the in-memory channel (no
//! codec, no copies) — and workers answer them through the shared
//! `worker::serve::execute_command`. Each round is a broadcast of one
//! command and a gather of m replies (a synchronous allreduce).
//!
//! The protocol is **allocation-free in steady state** (EXPERIMENTS.md
//! §Perf), pinned by the counting-allocator test
//! `rust/tests/alloc_steady_state.rs`:
//!
//! * transport is the single-slot rendezvous channel
//!   [`crate::comm::roundchan`] — no per-message queue nodes;
//! * broadcast payloads live in two persistent `Arc<Vec<f64>>` slots
//!   (`w`, `g`) that are rewritten in place once every worker has dropped
//!   its clone (always true after a gather, so `Arc::get_mut` succeeds
//!   round over round);
//! * reply vectors are pre-sized, travel leader -> worker inside the
//!   command, come back filled inside the reply, and return to the
//!   leader's pool — the same m buffers circulate forever;
//! * gradient / iterate averages accumulate in place into caller-owned
//!   buffers via the `*_into` trait methods.
//!
//! Failures are recoverable: when a worker reports an error (or dies),
//! the gather still drains every outstanding reply before surfacing the
//! *first* error, so the lockstep protocol never desynchronizes — a
//! failed round leaves the surviving cluster answering subsequent
//! rounds exactly like a fresh one.
//!
//! (The design brief calls for tokio; the offline build has no tokio, so
//! this engine uses std::thread + the in-tree channel — the same
//! ownership and message-flow structure, documented in DESIGN.md §5.)

use super::Cluster;
use crate::comm::roundchan::{
    round_channel, RecvTimeoutError, RoundReceiver, RoundSender,
};
use crate::comm::wire::{Command as Cmd, Reply};
use crate::comm::{Collective, CommStats, NetModel};
use crate::data::{shard_dataset, Dataset, Shard};
use crate::linalg::ops;
use crate::loss::Objective;
use crate::Result;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the leader waits on any single worker reply before calling
/// the worker wedged. Rounds are sub-second on every workload in tree;
/// a reply this late means a stuck thread, and surfacing an error beats
/// a silent deadlock. Override per cluster via
/// [`ThreadedCluster::set_reply_timeout`].
pub const DEFAULT_REPLY_TIMEOUT: Duration = Duration::from_secs(120);

struct WorkerHandle {
    tx: RoundSender<Cmd>,
    rx: RoundReceiver<Reply>,
    join: Option<JoinHandle<()>>,
}

/// Leader + m worker threads.
pub struct ThreadedCluster {
    handles: Vec<WorkerHandle>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
    /// n_i / N weights for exact gradient averaging.
    weights: Vec<f64>,
    /// cached mean squared row norm (counted once, like SerialCluster)
    row_sq: Option<f64>,
    // ---- round-persistent broadcast + reply scratch -----------------
    /// Broadcast slot for the iterate (w / w_prev).
    bcast_w: Arc<Vec<f64>>,
    /// Broadcast slot for the averaged gradient.
    bcast_g: Arc<Vec<f64>>,
    /// m recycled d-vectors: out to workers inside commands, back inside
    /// replies.
    reply_pool: Vec<Vec<f64>>,
    /// Per-reply wait budget (hang safety): a worker silent past this is
    /// reported wedged instead of deadlocking the leader.
    reply_timeout: Duration,
}

impl ThreadedCluster {
    pub fn new(ds: &Dataset, obj: Arc<dyn Objective>, m: usize, seed: u64) -> Self {
        Self::with_net(ds, obj, m, seed, NetModel::free())
    }

    pub fn with_net(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
    ) -> Self {
        Self::with_net_threads(ds, obj, m, seed, net, None)
    }

    /// [`ThreadedCluster::with_net`] with an explicit Gram-build thread
    /// count for every worker (config `threads`); None = the size
    /// ladder. The same count must be used on a serial cluster for the
    /// two engines to stay bit-identical.
    pub fn with_net_threads(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
    ) -> Self {
        let shards = shard_dataset(ds, m, seed);
        let d = ds.d();
        let total: usize = shards.iter().map(|s| s.n_effective()).sum();
        let weights: Vec<f64> = shards
            .iter()
            .map(|s| s.n_effective() as f64 / total as f64)
            .collect();
        let reply_pool = vec![vec![0.0; d]; shards.len()];
        let handles = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| spawn_worker(id, shard, obj.clone(), gram_threads))
            .collect();
        ThreadedCluster {
            handles,
            obj,
            comm: Collective::new(net),
            d,
            weights,
            row_sq: None,
            bcast_w: Arc::new(vec![0.0; d]),
            bcast_g: Arc::new(vec![0.0; d]),
            reply_pool,
            reply_timeout: DEFAULT_REPLY_TIMEOUT,
        }
    }

    /// Override the per-reply wait budget (tests use tight budgets to
    /// exercise the wedged-worker path quickly).
    pub fn set_reply_timeout(&mut self, timeout: Duration) {
        self.reply_timeout = timeout;
    }

    fn send_cmd(&self, i: usize, cmd: Cmd) -> Result<()> {
        self.handles[i]
            .tx
            .send(cmd)
            .map_err(|_| crate::Error::Runtime(format!("worker {i} channel closed")))
    }

    /// Receive worker i's reply, mapping worker-side failures, death
    /// *and* silence past the timeout to errors the same way every round
    /// does — a wedged worker surfaces as `Err`, never a deadlock.
    fn recv_reply(&self, i: usize) -> Result<Reply> {
        match self.handles[i].rx.recv_timeout(self.reply_timeout) {
            Ok(Reply::Err(e)) => Err(crate::Error::Runtime(format!("worker {i}: {e}"))),
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Disconnected) => {
                Err(crate::Error::Runtime(format!("worker {i} died mid-round")))
            }
            Err(RecvTimeoutError::Timeout) => Err(crate::Error::Runtime(format!(
                "worker {i} wedged: no reply within {:?}",
                self.reply_timeout
            ))),
        }
    }

    fn unexpected(&self, i: usize) -> crate::Error {
        crate::Error::Runtime(format!("worker {i}: unexpected reply type"))
    }

    /// Put a buffer-carrying reply's vector back into the pool slot it
    /// came from (drain path); non-carrying replies are dropped. Only
    /// fills slots the broadcast phase emptied, so pooled and
    /// worker-allocated replies can share the path.
    fn recycle(&mut self, i: usize, r: Reply) {
        match r {
            Reply::Vec(v) | Reply::VecScalar(v, _) => {
                if self.reply_pool[i].is_empty() {
                    self.reply_pool[i] = v;
                }
            }
            _ => {}
        }
    }

    /// Weighted gradient+loss gather into `g` — the uncounted body shared
    /// by the counted and instrumentation paths. Accumulates n_i-weighted
    /// in rank order, bit-identical to SerialCluster's reduction
    /// (smoke_cluster_parity). On failure every outstanding reply is
    /// still drained, so the lockstep protocol stays usable and only the
    /// first error surfaces.
    fn gather_grad_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        load_bcast(&mut self.bcast_w, w);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            let out = std::mem::take(&mut self.reply_pool[i]);
            match self.send_cmd(i, Cmd::GradLoss { w: self.bcast_w.clone(), out }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        g.fill(0.0);
        let mut loss = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::VecScalar(gi, li)) => {
                    if first_err.is_none() {
                        ops::axpy(self.weights[i], &gi, g);
                        loss += self.weights[i] * li;
                    }
                    self.reply_pool[i] = gi;
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }

    fn gather_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.gather_grad_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    /// Weighted loss-only gather (uncounted body; drains on failure).
    fn gather_loss(&mut self, w: &[f64]) -> Result<f64> {
        load_bcast(&mut self.bcast_w, w);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            match self.send_cmd(i, Cmd::Loss { w: self.bcast_w.clone() }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut loss = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Scalar(l)) => {
                    if first_err.is_none() {
                        loss += self.weights[i] * l;
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }
}

/// Rewrite a persistent broadcast slot in place when the leader holds the
/// only reference (true in steady state: every worker drops its clone
/// before replying, and the previous gather consumed all replies);
/// otherwise fall back to a fresh allocation.
fn load_bcast(slot: &mut Arc<Vec<f64>>, src: &[f64]) {
    match Arc::get_mut(slot) {
        Some(buf) if buf.len() == src.len() => buf.copy_from_slice(src),
        _ => *slot = Arc::new(src.to_vec()),
    }
}

fn spawn_worker(
    id: usize,
    shard: Shard,
    obj: Arc<dyn Objective>,
    gram_threads: Option<usize>,
) -> WorkerHandle {
    let (cmd_tx, cmd_rx) = round_channel::<Cmd>();
    let (rep_tx, rep_rx) = round_channel::<Reply>();
    let join = std::thread::Builder::new()
        .name(format!("dane-worker-{id}"))
        .spawn(move || {
            let mut worker = crate::worker::Worker::new(id, shard, obj);
            worker.set_gram_threads(gram_threads);
            // Leader dropping its endpoints disconnects the channel and
            // breaks both loops — no explicit shutdown message needed.
            // The command execution itself is the transport-shared
            // `worker::serve::execute_command`, so this engine answers
            // every wire command exactly like a TCP worker process.
            while let Ok(cmd) = cmd_rx.recv() {
                // execute_command consumes the command, dropping the
                // broadcast Arcs with it, so the leader's get_mut
                // succeeds next round.
                let reply = crate::worker::serve::execute_command(&mut worker, cmd);
                if rep_tx.send(reply).is_err() {
                    break;
                }
            }
        })
        .expect("spawn worker thread");
    WorkerHandle { tx: cmd_tx, rx: rep_rx, join: Some(join) }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        // Dropping the channel endpoints disconnects every worker: a
        // worker blocked in recv gets Err and exits; one mid-compute
        // fails its next reply send and exits.
        for h in self.handles.drain(..) {
            let WorkerHandle { tx, rx, join } = h;
            drop(tx);
            drop(rx);
            if let Some(j) = join {
                let _ = j.join();
            }
        }
    }
}

impl Cluster for ThreadedCluster {
    fn m(&self) -> usize {
        self.handles.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.grad_and_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let loss = self.gather_grad_loss_into(w, g)?;
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(loss)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let loss = self.gather_loss(w)?;
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; self.d];
        self.dane_round_into(w_prev, g, eta, mu, &mut acc)?;
        Ok(acc)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        load_bcast(&mut self.bcast_w, w_prev);
        load_bcast(&mut self.bcast_g, g);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            let buf = std::mem::take(&mut self.reply_pool[i]);
            let cmd = Cmd::DaneSolve {
                w_prev: self.bcast_w.clone(),
                g: self.bcast_g.clone(),
                eta,
                mu,
                out: buf,
            };
            match self.send_cmd(i, cmd) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        out.fill(0.0);
        let inv_m = 1.0 / self.handles.len() as f64;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Vec(wi)) => {
                    if first_err.is_none() {
                        // paper step (*): unweighted average in rank order
                        ops::axpy(inv_m, &wi, out);
                    }
                    self.reply_pool[i] = wi;
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(())
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        // Only rank 0 computes; everyone else idles this round. Not a
        // steady-state path, so the reply vector is freshly allocated by
        // the worker rather than pooled.
        load_bcast(&mut self.bcast_w, w_prev);
        load_bcast(&mut self.bcast_g, g);
        self.send_cmd(
            0,
            Cmd::DaneSolve {
                w_prev: self.bcast_w.clone(),
                g: self.bcast_g.clone(),
                eta,
                mu,
                out: Vec::new(),
            },
        )?;
        let w1 = match self.recv_reply(0)? {
            Reply::Vec(w) => w,
            _ => return Err(self.unexpected(0)),
        };
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(w1)
    }

    fn prox_all(&mut self, targets: &[Vec<f64>], rho: f64) -> Result<Vec<Vec<f64>>> {
        assert_eq!(targets.len(), self.m());
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for (i, v) in targets.iter().enumerate() {
            match self.send_cmd(i, Cmd::Prox { v: v.clone(), rho }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut out = Vec::with_capacity(self.m());
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Vec(w)) => {
                    if first_err.is_none() {
                        out.push(w);
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Vec<f64>>, Option<Vec<Vec<f64>>>)> {
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            match self.send_cmd(i, Cmd::Erm { subsample }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut full = Vec::with_capacity(self.m());
        let mut subs: Vec<Vec<f64>> = Vec::new();
        let mut any_sub = false;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::VecPair(f, s)) => {
                    if first_err.is_none() {
                        full.push(f);
                        if let Some(s) = s {
                            subs.push(s);
                            any_sub = true;
                        }
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok((full, if any_sub { Some(subs) } else { None }))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        out
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        if let Some(v) = self.row_sq {
            return Ok(v);
        }
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            match self.send_cmd(i, Cmd::RowSq) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut total = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Scalar(v)) => {
                    if first_err.is_none() {
                        total += self.weights[i] * v;
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, 1);
        self.row_sq = Some(total);
        Ok(total)
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.gather_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.gather_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        self.comm.stats().clone()
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{dane, RunCtx, SerialCluster};
    use crate::data::synthetic_fig2;
    use crate::loss::Ridge;
    use crate::solver::erm_solve;

    fn fixture() -> (Dataset, Arc<dyn Objective>, f64) {
        let lam = 0.01;
        let ds = synthetic_fig2(1024, 12, lam / 2.0, 7);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        (ds, obj, phi_star)
    }

    #[test]
    fn threaded_matches_serial_exactly() {
        let (ds, obj, _) = fixture();
        let mut serial = SerialCluster::new(&ds, obj.clone(), 4, 3);
        let mut threaded = ThreadedCluster::new(&ds, obj, 4, 3);
        let w = vec![0.1; 12];
        let (g1, l1) = serial.grad_and_loss(&w).unwrap();
        let (g2, l2) = threaded.grad_and_loss(&w).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);

        let d1 = serial.dane_round(&w, &g1, 1.0, 0.01).unwrap();
        let d2 = threaded.dane_round(&w, &g2, 1.0, 0.01).unwrap();
        for j in 0..12 {
            assert!((d1[j] - d2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn into_paths_match_allocating_paths_bitwise() {
        let (ds, obj, _) = fixture();
        let mut a = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let mut b = ThreadedCluster::new(&ds, obj, 4, 3);
        let w = vec![0.1; 12];
        let (g1, l1) = a.grad_and_loss(&w).unwrap();
        let mut g2 = vec![0.0; 12];
        let l2 = b.grad_and_loss_into(&w, &mut g2).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        let d1 = a.dane_round(&w, &g1, 1.0, 0.01).unwrap();
        let mut d2 = vec![0.0; 12];
        b.dane_round_into(&w, &g2, 1.0, 0.01, &mut d2).unwrap();
        assert_eq!(d1, d2);
    }

    #[test]
    fn broadcast_slots_are_reused_in_steady_state() {
        let (ds, obj, _) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        let mut w = vec![0.1; 12];
        let mut g = vec![0.0; 12];
        let mut w_next = vec![0.0; 12];
        cluster.grad_and_loss_into(&w, &mut g).unwrap();
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        let wp = Arc::as_ptr(&cluster.bcast_w);
        let gp = Arc::as_ptr(&cluster.bcast_g);
        for _ in 0..5 {
            std::mem::swap(&mut w, &mut w_next);
            cluster.grad_and_loss_into(&w, &mut g).unwrap();
            cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
            assert_eq!(Arc::as_ptr(&cluster.bcast_w), wp, "w slot reallocated");
            assert_eq!(Arc::as_ptr(&cluster.bcast_g), gp, "g slot reallocated");
            assert_eq!(Arc::strong_count(&cluster.bcast_w), 1);
        }
    }

    #[test]
    fn full_dane_run_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-9);
        let res = dane::run(&mut cluster, &Default::default(), &ctx).unwrap();
        assert!(res.converged, "{:?}", res.trace.suboptimality());
        // per completed iteration k: k+1 gradient rounds + k iterate rounds
        let last = res.trace.rows.last().unwrap();
        assert_eq!(last.comm_rounds, 2 * last.round as u64 + 1);
    }

    #[test]
    fn admm_and_osa_work_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-7);
        let res = crate::coordinator::admm::run(
            &mut cluster,
            &crate::coordinator::admm::AdmmOptions { rho: 0.1 },
            &ctx,
        )
        .unwrap();
        assert!(res.converged);

        let mut cluster = ThreadedCluster::new(&ds, obj, 8, 3);
        let ctx = RunCtx::new(1).with_reference(phi_star);
        let res = crate::coordinator::osa::run(
            &mut cluster,
            &crate::coordinator::osa::OsaOptions {
                bias_correction_r: Some(0.5),
                seed: 1,
            },
            &ctx,
        )
        .unwrap();
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 1);
    }

    #[test]
    fn worker_thread_shutdown_is_clean() {
        let (ds, obj, _) = fixture();
        let cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        drop(cluster); // must not hang or panic
    }

    #[test]
    fn worker_error_does_not_desync_later_rounds() {
        use crate::linalg::{DataMatrix, DenseMatrix};
        // zero feature column -> singular Gram; lam = 0, mu = 0 makes the
        // cached-Cholesky local solve fail with a nonpositive pivot
        let mut rng = crate::util::Rng64::seed_from_u64(3);
        let mut x = DenseMatrix::zeros(32, 4);
        for i in 0..32 {
            for j in 0..3 {
                x.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        let y: Vec<f64> = (0..32).map(|i| (i % 3) as f64 - 1.0).collect();
        let ds = Dataset::new("degenerate", DataMatrix::Dense(x), y);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.0));
        let mut t = ThreadedCluster::new(&ds, obj.clone(), 4, 1);
        let w = vec![0.0; 4];
        let (g, _) = t.grad_and_loss(&w).unwrap();
        assert!(
            t.dane_round(&w, &g, 1.0, 0.0).is_err(),
            "singular local solve must surface an error"
        );
        // the failed round must have drained every reply: the survivor
        // and a fresh cluster agree bit-for-bit on the next rounds
        let mut fresh = ThreadedCluster::new(&ds, obj, 4, 1);
        fresh.grad_and_loss(&w).unwrap();
        let (g1, l1) = t.grad_and_loss(&w).unwrap();
        let (g2, l2) = fresh.grad_and_loss(&w).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
        assert_eq!(t.loss_only(&w).unwrap(), fresh.loss_only(&w).unwrap());
    }
}
