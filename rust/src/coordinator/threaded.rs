//! Threaded message-passing cluster engine.
//!
//! Where [`super::SerialCluster`] drives workers inline (deterministic,
//! the measurement engine for every figure), `ThreadedCluster` runs each
//! worker on its own OS thread behind a command/reply protocol — the
//! actual leader/worker process topology a deployment would have, minus
//! the sockets. Messages are the typed [`crate::comm::wire`]
//! `Command`/`Reply` enums — the same protocol `TcpCluster` moves over
//! real sockets, here passed by value through the in-memory channel (no
//! codec, no copies) — and workers answer them through the shared
//! `worker::serve::execute_command`. Each round is a broadcast of one
//! command and a gather of m replies (a synchronous allreduce).
//!
//! The protocol is **allocation-free in steady state** (EXPERIMENTS.md
//! §Perf), pinned by the counting-allocator test
//! `rust/tests/alloc_steady_state.rs`:
//!
//! * transport is the single-slot rendezvous channel
//!   [`crate::comm::roundchan`] — no per-message queue nodes;
//! * broadcast payloads live in two persistent `Arc<Vec<f64>>` slots
//!   (`w`, `g`) that are rewritten in place once every worker has dropped
//!   its clone (always true after a gather, so `Arc::get_mut` succeeds
//!   round over round);
//! * reply vectors are pre-sized, travel leader -> worker inside the
//!   command, come back filled inside the reply, and return to the
//!   leader's pool — the same m buffers circulate forever;
//! * gradient / iterate averages accumulate in place into caller-owned
//!   buffers via the `*_into` trait methods;
//! * fold-type collectives reduce **incrementally in rank order**: the
//!   star gather's blocking per-rank receive loop folds each reply the
//!   moment it lands, and the tree wiring routes through
//!   [`RankGather::drain_fold`] (`tree_round_fold`), which consumes the
//!   ready rank prefix while later links are still draining. Both orders
//!   are the exact rank-0..m-1 fold, so the bits match the buffered
//!   reduction and every other engine.
//!
//! Failures are recoverable: when a worker reports an error (or dies),
//! the gather still drains every outstanding reply before surfacing the
//! *first* error, so the lockstep protocol never desynchronizes — a
//! failed round leaves the surviving cluster answering subsequent
//! rounds exactly like a fresh one.
//!
//! (The design brief calls for tokio; the offline build has no tokio, so
//! this engine uses std::thread + the in-tree channel — the same
//! ownership and message-flow structure, documented in DESIGN.md §5.)

use super::Cluster;
use crate::comm::compress::{Codec, LeaderCompressor};
use crate::comm::roundchan::{
    round_channel, RecvTimeoutError, RoundReceiver, RoundSender,
};
use crate::comm::topology::{ExecTopology, RankGather, TreePlan, RELAY_CHILD_LOST};
use crate::comm::wire::{Command as Cmd, Reply};
use crate::comm::{Collective, CommStats, NetModel};
use crate::data::{shard_dataset, Dataset, Shard};
use crate::linalg::ops;
use crate::loss::Objective;
use crate::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the leader waits on any single worker reply before calling
/// the worker wedged. Rounds are sub-second on every workload in tree;
/// a reply this late means a stuck thread, and surfacing an error beats
/// a silent deadlock. Override per cluster via
/// [`ThreadedCluster::set_reply_timeout`].
pub const DEFAULT_REPLY_TIMEOUT: Duration = Duration::from_secs(120);

struct WorkerHandle {
    tx: RoundSender<Cmd>,
    rx: RoundReceiver<Reply>,
    join: Option<JoinHandle<()>>,
}

/// Which fold a compressed round performs: the n_i/N-weighted gradient
/// average or the paper's unweighted 1/|alive| iterate average.
#[derive(Clone, Copy)]
enum FoldWeights {
    Grad,
    Solve,
}

/// One leader-adjacent link of the tree wiring: the root child's
/// channels, carrying its whole subtree's replies in preorder
/// (`ranks`), exactly like a TCP root link carries preorder frames.
struct TreeRootLink {
    ranks: Vec<usize>,
    tx: RoundSender<Cmd>,
    rx: RoundReceiver<Reply>,
    /// Latched after a reply-budget timeout: the wedged subtree may put
    /// a *stale* reply in the rendezvous slot later, and reading it
    /// would attribute an old round's value to a new round. A latched
    /// link fails every later round fast instead.
    dead: Option<String>,
}

/// One downstream link held by a relaying worker thread.
struct TreeChildLink {
    rank: usize,
    ranks: Vec<usize>,
    tx: RoundSender<Cmd>,
    rx: RoundReceiver<Reply>,
}

/// Binomial-relay wiring: the leader holds only the root links; every
/// other channel pair lives between a worker and its tree parent.
struct TreeWiring {
    links: Vec<TreeRootLink>,
    joins: Vec<Option<JoinHandle<()>>>,
}

/// Retained rebuild inputs for [`Cluster::recover`]: the shards workers
/// were built from (threads are stateless between rounds — respawning
/// from the same shard reproduces the worker exactly) and the
/// Gram-build thread count they must keep for bit-parity.
struct RecoveryCtx {
    shards: Vec<Shard>,
    gram_threads: Option<usize>,
}

/// Leader + m worker threads.
pub struct ThreadedCluster {
    /// Star wiring: one command/reply channel pair per worker (empty in
    /// tree mode).
    handles: Vec<WorkerHandle>,
    /// Tree wiring (`ExecTopology::Tree`); `None` for the star
    /// strategies.
    tree: Option<TreeWiring>,
    /// Per-worker kill switches (fault-injection tests): a flagged
    /// worker exits on its next command without replying, exactly like
    /// a SIGKILLed process — its channels disconnect and, in tree mode,
    /// its whole subtree unwinds.
    kills: Vec<Arc<AtomicBool>>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
    /// n_i / N weights for exact gradient averaging.
    weights: Vec<f64>,
    /// Fold weights actually applied: bitwise equal to `weights` while
    /// every rank is alive; renormalized over survivors (dead ranks
    /// 0.0) after a `degrade` recovery.
    eff_weights: Vec<f64>,
    /// Quarantined ranks (`degrade` policy). All-false fault-free.
    dead: Vec<bool>,
    /// Ranks currently participating in collectives.
    n_alive: usize,
    /// Everything a post-fault rebuild needs; armed by
    /// [`Cluster::enable_recovery`], `None` on unsupervised runs.
    recovery: Option<RecoveryCtx>,
    /// cached mean squared row norm (counted once, like SerialCluster)
    row_sq: Option<f64>,
    // ---- round-persistent broadcast + reply scratch -----------------
    /// Broadcast slot for the iterate (w / w_prev).
    bcast_w: Arc<Vec<f64>>,
    /// Broadcast slot for the averaged gradient.
    bcast_g: Arc<Vec<f64>>,
    /// m recycled d-vectors: out to workers inside commands, back inside
    /// replies.
    reply_pool: Vec<Vec<f64>>,
    /// Per-reply wait budget (hang safety): a worker silent past this is
    /// reported wedged instead of deadlocking the leader.
    reply_timeout: Duration,
    /// Leader-side codec + error-feedback state for compressed round
    /// payloads ([`ThreadedCluster::set_compression`]). `None` runs the
    /// uncompressed protocol, bit-identical to before the knob existed.
    /// Compressed rounds trade the zero-allocation steady state for the
    /// smaller (well, in-memory: cheaper-to-model) payloads; the
    /// alloc-pinned path is the uncompressed one.
    compressor: Option<LeaderCompressor>,
    /// Decode scratch for compressed replies.
    dec: Vec<f64>,
    /// Pooled rank gather for the tree wiring's fold-type collectives;
    /// re-armed (capacity retained) by every `tree_round_fold`. The star
    /// wiring needs none: its blocking per-rank receive loop *is* an
    /// incremental rank-order fold already.
    gather: RankGather,
}

impl ThreadedCluster {
    pub fn new(ds: &Dataset, obj: Arc<dyn Objective>, m: usize, seed: u64) -> Self {
        Self::with_net(ds, obj, m, seed, NetModel::free())
    }

    pub fn with_net(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
    ) -> Self {
        Self::with_net_threads(ds, obj, m, seed, net, None)
    }

    /// [`ThreadedCluster::with_net`] with an explicit Gram-build thread
    /// count for every worker (config `threads`); None = the size
    /// ladder. The same count must be used on a serial cluster for the
    /// two engines to stay bit-identical.
    pub fn with_net_threads(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
    ) -> Self {
        Self::with_topology(ds, obj, m, seed, net, gram_threads, ExecTopology::Star)
    }

    /// Full constructor: like [`ThreadedCluster::with_net_threads`] with
    /// an explicit collective execution topology. The star strategies
    /// share one wiring — the per-worker worker threads *are* the
    /// parallel star's I/O actors, so sequential and parallel star
    /// coincide in memory (the distinction is real on `TcpCluster`,
    /// where writes and reads serialize on actual sockets). `Tree`
    /// builds the binomial relay wiring instead: the leader talks to
    /// O(log m) root children and interior workers relay
    /// ([`crate::comm::topology::TreePlan`]). Traces are bit-identical
    /// across all three — the reduction is always a rank-order fold at
    /// the root.
    pub fn with_topology(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        topology: ExecTopology,
    ) -> Self {
        let shards = shard_dataset(ds, m, seed);
        let d = ds.d();
        let total: usize = shards.iter().map(|s| s.n_effective()).sum();
        let weights: Vec<f64> = shards
            .iter()
            .map(|s| s.n_effective() as f64 / total as f64)
            .collect();
        let kills: Vec<Arc<AtomicBool>> =
            (0..shards.len()).map(|_| Arc::new(AtomicBool::new(false))).collect();
        // The reply pool only serves the star wiring (tree replies
        // bundle up through the relays); the broadcast slots serve both
        // wirings — tree rounds relay `Arc` clones of the same slots.
        let star = !topology.is_tree();
        let reply_pool =
            if star { vec![vec![0.0; d]; shards.len()] } else { Vec::new() };
        let slot = || Arc::new(vec![0.0; d]);
        let (bcast_w, bcast_g) = (slot(), slot());
        let (handles, tree) = if topology.is_tree() {
            (Vec::new(), Some(build_tree_wiring(shards, &obj, gram_threads, &kills)))
        } else {
            let handles = shards
                .into_iter()
                .enumerate()
                .map(|(id, shard)| {
                    spawn_worker(id, shard, obj.clone(), gram_threads, kills[id].clone())
                })
                .collect();
            (handles, None)
        };
        let n_alive = weights.len();
        ThreadedCluster {
            handles,
            tree,
            kills,
            obj,
            comm: Collective::new(net),
            d,
            eff_weights: weights.clone(),
            dead: vec![false; n_alive],
            n_alive,
            recovery: None,
            weights,
            row_sq: None,
            bcast_w,
            bcast_g,
            reply_pool,
            reply_timeout: DEFAULT_REPLY_TIMEOUT,
            compressor: None,
            dec: Vec::new(),
            gather: RankGather::new(n_alive),
        }
    }

    /// Compress the O(d) round payloads (GradLoss / DaneSolve and their
    /// replies) with `codec`, optionally with error feedback. Eval
    /// instrumentation gathers and the Theorem-5 first round stay
    /// uncompressed — only the counted optimization rounds go through
    /// the codec, on both the star and tree wirings (the tree relays
    /// the one shared `Arc` payload without re-expanding it).
    pub fn set_compression(&mut self, codec: Codec, error_feedback: bool, seed: u64) {
        self.compressor = Some(LeaderCompressor::new(codec, error_feedback, seed));
    }

    /// Flip worker `i`'s kill switch: it exits on its next command
    /// without replying — the in-memory analog of SIGKILLing a worker
    /// process, deterministic for fault-injection tests. In tree mode a
    /// killed interior node takes its whole subtree's channels down;
    /// the round that observes it surfaces `Err` and drains cleanly.
    pub fn kill_worker(&mut self, i: usize) {
        self.kills[i].store(true, Ordering::Relaxed);
    }

    /// Override the per-reply wait budget (tests use tight budgets to
    /// exercise the wedged-worker path quickly).
    pub fn set_reply_timeout(&mut self, timeout: Duration) {
        self.reply_timeout = timeout;
    }

    fn send_cmd(&self, i: usize, cmd: Cmd) -> Result<()> {
        self.handles[i]
            .tx
            .send(cmd)
            .map_err(|_| crate::Error::WorkerLost(format!("worker {i} channel closed")))
    }

    /// Receive worker i's reply, mapping worker-side failures, death
    /// *and* silence past the timeout to errors the same way every round
    /// does — a wedged worker surfaces as `Err`, never a deadlock.
    /// Transport death ([`crate::Error::WorkerLost`]) is the recoverable
    /// class; a worker-*reported* error stays `Runtime` — the compute
    /// failed and would fail again on a respawned replacement.
    fn recv_reply(&self, i: usize) -> Result<Reply> {
        match self.handles[i].rx.recv_timeout(self.reply_timeout) {
            Ok(Reply::Err(e)) => Err(crate::Error::Runtime(format!("worker {i}: {e}"))),
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Disconnected) => {
                Err(crate::Error::WorkerLost(format!("worker {i} died mid-round")))
            }
            Err(RecvTimeoutError::Timeout) => Err(crate::Error::WorkerLost(format!(
                "worker {i} wedged: no reply within {:?}",
                self.reply_timeout
            ))),
        }
    }

    fn unexpected(&self, i: usize) -> crate::Error {
        crate::Error::Runtime(format!("worker {i}: unexpected reply type"))
    }

    /// Put a buffer-carrying reply's vector back into the pool slot it
    /// came from (drain path); non-carrying replies are dropped. Only
    /// fills slots the broadcast phase emptied, so pooled and
    /// worker-allocated replies can share the path.
    fn recycle(&mut self, i: usize, r: Reply) {
        match r {
            Reply::Vec(v) | Reply::VecScalar(v, _) => {
                if self.reply_pool[i].is_empty() {
                    self.reply_pool[i] = v;
                }
            }
            _ => {}
        }
    }

    // ---- tree-relay leader side -------------------------------------

    /// One broadcast round over the tree wiring: send `cmd` down every
    /// root link, collect each link's preorder reply bundle, slot by
    /// rank, surface the lowest-rank error after draining everything.
    /// A link that disconnects or goes silent past the reply budget has
    /// its remaining ranks answered with errors immediately — no
    /// per-rank timeout stacking.
    fn tree_round(&mut self, cmd: &Cmd) -> Result<Vec<Reply>> {
        let m = self.weights.len();
        let timeout = self.reply_timeout;
        let tree = self.tree.as_mut().ok_or_else(|| {
            crate::Error::Runtime("tree round on a cluster without tree wiring".into())
        })?;
        let mut gather = RankGather::new(m);
        let mut sent = Vec::with_capacity(tree.links.len());
        for l in &tree.links {
            sent.push(l.dead.is_none() && l.tx.send(cmd.relay_copy()).is_ok());
        }
        for (li, l) in tree.links.iter_mut().enumerate() {
            let mut dead: Option<String> = if let Some(msg) = &l.dead {
                Some(msg.clone())
            } else if sent[li] {
                None
            } else {
                Some(format!("worker {} died before the round", l.ranks[0]))
            };
            let mut latch: Option<String> = None;
            for &rank in &l.ranks {
                let res = match &dead {
                    Some(msg) => Err(crate::Error::WorkerLost(msg.clone())),
                    None => match l.rx.recv_timeout(timeout) {
                        Ok(rep) => Ok(rep),
                        Err(RecvTimeoutError::Disconnected) => {
                            let msg =
                                format!("worker {} died mid-round", l.ranks[0]);
                            dead = Some(msg.clone());
                            Err(crate::Error::WorkerLost(msg))
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            // A wedged (alive) subtree may still deliver
                            // this round's replies later — latch the
                            // link so they are never read as a future
                            // round's values.
                            let msg = format!(
                                "worker {} wedged: no reply within {timeout:?}",
                                l.ranks[0]
                            );
                            dead = Some(msg.clone());
                            latch = Some(msg.clone());
                            Err(crate::Error::WorkerLost(msg))
                        }
                    },
                };
                gather.put(rank, res);
            }
            if latch.is_some() {
                l.dead = latch;
            }
        }
        gather.into_result()
    }

    /// [`tree_round`] with **incremental rank-prefix folding**: replies
    /// slot into the pooled gather as each link delivers its preorder
    /// bundle, and [`RankGather::drain_fold`] consumes the ready rank
    /// prefix after every link — the fold runs in exact rank order while
    /// later links are still draining, without ever buffering the full
    /// reply set. Send/latch/error discipline is identical to
    /// [`tree_round`] (tree mode never carries quarantined ranks — the
    /// recovery path rebuilds as a star — so the dead mask is all-live
    /// and `finish_fold` degenerates to the unmasked contract).
    ///
    /// [`tree_round`]: Self::tree_round
    fn tree_round_fold(
        &mut self,
        cmd: &Cmd,
        fold: &mut dyn FnMut(usize, Reply) -> Result<()>,
    ) -> Result<()> {
        let m = self.weights.len();
        let timeout = self.reply_timeout;
        let ThreadedCluster { tree, gather, dead: dead_ranks, .. } = self;
        let tree = tree.as_mut().ok_or_else(|| {
            crate::Error::Runtime("tree round on a cluster without tree wiring".into())
        })?;
        gather.reset(m);
        let mut sent = Vec::with_capacity(tree.links.len());
        for l in &tree.links {
            sent.push(l.dead.is_none() && l.tx.send(cmd.relay_copy()).is_ok());
        }
        for (li, l) in tree.links.iter_mut().enumerate() {
            let mut dead: Option<String> = if let Some(msg) = &l.dead {
                Some(msg.clone())
            } else if sent[li] {
                None
            } else {
                Some(format!("worker {} died before the round", l.ranks[0]))
            };
            let mut latch: Option<String> = None;
            for &rank in &l.ranks {
                let res = match &dead {
                    Some(msg) => Err(crate::Error::WorkerLost(msg.clone())),
                    None => match l.rx.recv_timeout(timeout) {
                        Ok(rep) => Ok(rep),
                        Err(RecvTimeoutError::Disconnected) => {
                            let msg =
                                format!("worker {} died mid-round", l.ranks[0]);
                            dead = Some(msg.clone());
                            Err(crate::Error::WorkerLost(msg))
                        }
                        Err(RecvTimeoutError::Timeout) => {
                            // same latch as tree_round: a wedged subtree's
                            // late replies must never be read as a future
                            // round's values.
                            let msg = format!(
                                "worker {} wedged: no reply within {timeout:?}",
                                l.ranks[0]
                            );
                            dead = Some(msg.clone());
                            latch = Some(msg.clone());
                            Err(crate::Error::WorkerLost(msg))
                        }
                    },
                };
                gather.put(rank, res);
            }
            if latch.is_some() {
                l.dead = latch;
            }
            gather.drain_fold(dead_ranks, fold);
        }
        gather.finish_fold(dead_ranks, fold)
    }

    /// Point-to-point round over the tree wiring: a `For` envelope down
    /// the link holding `rank`, one reply back. Only the path nodes are
    /// touched — the rest of the cluster idles, like the star engines'
    /// single-worker sends.
    fn tree_single(&mut self, rank: usize, cmd: Cmd) -> Result<Reply> {
        let timeout = self.reply_timeout;
        let tree = self.tree.as_mut().ok_or_else(|| {
            crate::Error::Runtime("tree round on a cluster without tree wiring".into())
        })?;
        let link = tree
            .links
            .iter_mut()
            .find(|l| l.ranks.contains(&rank))
            .ok_or_else(|| {
                crate::Error::Runtime(format!("no tree link holds worker {rank}"))
            })?;
        if let Some(msg) = &link.dead {
            return Err(crate::Error::WorkerLost(msg.clone()));
        }
        link.tx
            .send(Cmd::For { rank, inner: Box::new(cmd) })
            .map_err(|_| {
                crate::Error::WorkerLost(format!(
                    "worker {} died mid-round",
                    link.ranks[0]
                ))
            })?;
        match link.rx.recv_timeout(timeout) {
            Ok(Reply::Err(e)) => {
                Err(crate::Error::Runtime(format!("worker {rank}: {e}")))
            }
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Disconnected) => {
                Err(crate::Error::WorkerLost(format!(
                    "worker {} died mid-round",
                    link.ranks[0]
                )))
            }
            Err(RecvTimeoutError::Timeout) => {
                // see tree_round: a late reply must not leak into a
                // future round — latch the link dead.
                let msg = format!(
                    "worker {} wedged: no reply within {timeout:?}",
                    link.ranks[0]
                );
                link.dead = Some(msg.clone());
                Err(crate::Error::WorkerLost(msg))
            }
        }
    }

    /// Tree-mode gradient+loss gather: incremental rank-order weighted
    /// fold via [`tree_round_fold`] — bit-identical to the star engines'
    /// reduction (same rank order, same axpy per rank).
    ///
    /// [`tree_round_fold`]: Self::tree_round_fold
    fn tree_grad_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        load_bcast(&mut self.bcast_w, w);
        let cmd = Cmd::GradLoss { w: self.bcast_w.clone(), out: Vec::new() };
        g.fill(0.0);
        let mut loss = 0.0;
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.tree_round_fold(&cmd, &mut |i, r| match r {
            Reply::VecScalar(gi, li) if gi.len() == g.len() => {
                ops::axpy(eff[i], &gi, g);
                loss += eff[i] * li;
                Ok(())
            }
            _ => Err(crate::Error::Runtime(format!(
                "worker {i}: unexpected reply type"
            ))),
        });
        self.eff_weights = eff;
        res?;
        Ok(loss)
    }

    fn tree_loss(&mut self, w: &[f64]) -> Result<f64> {
        load_bcast(&mut self.bcast_w, w);
        let cmd = Cmd::Loss { w: self.bcast_w.clone() };
        let mut loss = 0.0;
        let eff = std::mem::take(&mut self.eff_weights);
        let res = self.tree_round_fold(&cmd, &mut |i, r| match r {
            Reply::Scalar(l) => {
                loss += eff[i] * l;
                Ok(())
            }
            _ => Err(crate::Error::Runtime(format!(
                "worker {i}: unexpected reply type"
            ))),
        });
        self.eff_weights = eff;
        res?;
        Ok(loss)
    }

    /// Weighted gradient+loss gather into `g` — the uncounted body shared
    /// by the counted and instrumentation paths. Accumulates n_i-weighted
    /// in rank order, bit-identical to SerialCluster's reduction
    /// (smoke_cluster_parity). On failure every outstanding reply is
    /// still drained, so the lockstep protocol stays usable and only the
    /// first error surfaces.
    fn gather_grad_loss_into(
        &mut self,
        w: &[f64],
        g: &mut [f64],
        compress: bool,
    ) -> Result<f64> {
        if compress && self.compressor.is_some() {
            return self.gather_grad_loss_compressed(w, g);
        }
        if self.tree.is_some() {
            return self.tree_grad_loss_into(w, g);
        }
        load_bcast(&mut self.bcast_w, w);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            let out = std::mem::take(&mut self.reply_pool[i]);
            match self.send_cmd(i, Cmd::GradLoss { w: self.bcast_w.clone(), out }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        g.fill(0.0);
        let mut loss = 0.0;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::VecScalar(gi, li)) => {
                    if first_err.is_none() {
                        ops::axpy(self.eff_weights[i], &gi, g);
                        loss += self.eff_weights[i] * li;
                    }
                    self.reply_pool[i] = gi;
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }

    fn gather_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        // instrumentation path: always uncompressed (full-precision
        // objective read-outs, never part of the optimization loop)
        let loss = self.gather_grad_loss_into(w, &mut g, false)?;
        Ok((g, loss))
    }

    // ---- compressed rounds ------------------------------------------

    /// Compressed gradient+loss round: one `Arc`'d `CompressedVec`
    /// command shared by every rank (tree links relay the same payload),
    /// replies decoded through the leader's scratch and folded in rank
    /// order exactly like the uncompressed gather.
    fn gather_grad_loss_compressed(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let Some(comp) = self.compressor.as_mut() else {
            return Err(crate::Error::Runtime(
                "compressed gather without a compressor".into(),
            ));
        };
        let payload = Arc::new(comp.grad_cmd(w));
        let mut dec = std::mem::take(&mut self.dec);
        let res = self.fold_compressed_round(
            Cmd::CompressedVec(payload),
            &mut dec,
            FoldWeights::Grad,
            g,
        );
        self.dec = dec;
        res
    }

    /// Compressed DANE local-solve round; the iterate average uses the
    /// paper's unweighted 1/|alive| fold, like the uncompressed path.
    fn dane_round_compressed(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        let Some(comp) = self.compressor.as_mut() else {
            return Err(crate::Error::Runtime(
                "compressed round without a compressor".into(),
            ));
        };
        let payload = Arc::new(comp.solve_cmd(w_prev, g, eta, mu));
        let mut dec = std::mem::take(&mut self.dec);
        let res = self.fold_compressed_round(
            Cmd::CompressedVec(payload),
            &mut dec,
            FoldWeights::Solve,
            out,
        );
        self.dec = dec;
        res.map(|_| ())
    }

    /// Broadcast one compressed command and fold the compressed replies
    /// in rank order. Returns the weighted loss for gradient rounds
    /// (0.0 for solve rounds, whose replies carry no scalar). Shares the
    /// star drain discipline with the uncompressed gathers: on failure
    /// every outstanding reply is still consumed so the lockstep
    /// protocol never desynchronizes.
    fn fold_compressed_round(
        &mut self,
        cmd: Cmd,
        dec: &mut Vec<f64>,
        weights: FoldWeights,
        acc: &mut [f64],
    ) -> Result<f64> {
        let inv_alive = 1.0 / self.n_alive as f64;
        let fold_w = |this: &Self, i: usize| match weights {
            FoldWeights::Grad => this.eff_weights[i],
            FoldWeights::Solve => inv_alive,
        };
        let want_loss = matches!(weights, FoldWeights::Grad);
        if self.tree.is_some() {
            acc.fill(0.0);
            let mut loss = 0.0;
            let eff = std::mem::take(&mut self.eff_weights);
            let res = self.tree_round_fold(&cmd, &mut |i, r| match r {
                Reply::CompressedVec(cr)
                    if cr.vec.dim() == acc.len()
                        && cr.loss.is_some() == want_loss =>
                {
                    let wgt = match weights {
                        FoldWeights::Grad => eff[i],
                        FoldWeights::Solve => inv_alive,
                    };
                    cr.vec.decode_into(dec);
                    ops::axpy(wgt, dec, acc);
                    loss += wgt * cr.loss.unwrap_or(0.0);
                    Ok(())
                }
                _ => Err(crate::Error::Runtime(format!(
                    "worker {i}: unexpected reply type"
                ))),
            });
            self.eff_weights = eff;
            res?;
            return Ok(loss);
        }
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            match self.send_cmd(i, cmd.relay_copy()) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        acc.fill(0.0);
        let mut loss = 0.0;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::CompressedVec(cr))
                    if cr.vec.dim() == acc.len()
                        && cr.loss.is_some() == want_loss =>
                {
                    if first_err.is_none() {
                        cr.vec.decode_into(dec);
                        ops::axpy(fold_w(self, i), dec, acc);
                        loss += fold_w(self, i) * cr.loss.unwrap_or(0.0);
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }

    /// Weighted loss-only gather (uncounted body; drains on failure).
    fn gather_loss(&mut self, w: &[f64]) -> Result<f64> {
        if self.tree.is_some() {
            return self.tree_loss(w);
        }
        load_bcast(&mut self.bcast_w, w);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            match self.send_cmd(i, Cmd::Loss { w: self.bcast_w.clone() }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut loss = 0.0;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::Scalar(l)) => {
                    if first_err.is_none() {
                        loss += self.eff_weights[i] * l;
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }
}

/// Rewrite a persistent broadcast slot in place when the leader holds the
/// only reference (true in steady state: every worker drops its clone
/// before replying, and the previous gather consumed all replies);
/// otherwise fall back to a fresh allocation.
fn load_bcast(slot: &mut Arc<Vec<f64>>, src: &[f64]) {
    match Arc::get_mut(slot) {
        Some(buf) if buf.len() == src.len() => buf.copy_from_slice(src),
        _ => *slot = Arc::new(src.to_vec()),
    }
}

fn spawn_worker(
    id: usize,
    shard: Shard,
    obj: Arc<dyn Objective>,
    gram_threads: Option<usize>,
    kill: Arc<AtomicBool>,
) -> WorkerHandle {
    let (cmd_tx, cmd_rx) = round_channel::<Cmd>();
    let (rep_tx, rep_rx) = round_channel::<Reply>();
    let builder = std::thread::Builder::new().name(format!("dane-worker-{id}"));
    let join = super::must_spawn(builder, move || {
            let mut worker = crate::worker::Worker::new(id, shard, obj);
            worker.set_gram_threads(gram_threads);
            // Leader dropping its endpoints disconnects the channel and
            // breaks both loops — no explicit shutdown message needed.
            // The command execution itself is the transport-shared
            // `worker::serve::execute_command`, so this engine answers
            // every wire command exactly like a TCP worker process.
            while let Ok(cmd) = cmd_rx.recv() {
                // A flagged worker dies silently mid-round, like a
                // SIGKILLed process: channels disconnect, no reply.
                if kill.load(Ordering::Relaxed) {
                    return;
                }
                // execute_command consumes the command, dropping the
                // broadcast Arcs with it, so the leader's get_mut
                // succeeds next round.
                let reply = crate::worker::serve::execute_command(&mut worker, cmd);
                if rep_tx.send(reply).is_err() {
                    break;
                }
            }
    });
    WorkerHandle { tx: cmd_tx, rx: rep_rx, join: Some(join) }
}

/// Take a channel end out of the wiring table exactly once. The tree
/// plan visits every rank once as a child (or root link) and once as
/// itself, so a second claim is a construction-order bug in this file,
/// not a runtime condition — abort loudly rather than wiring a cluster
/// that would deadlock on round one.
fn claim<T>(slot: &mut Option<T>, what: &str, rank: usize) -> T {
    // lint:allow(panic-freedom): double-claim is a local wiring bug caught at bring-up, never reachable from worker input
    slot.take().unwrap_or_else(|| panic!("{what} for rank {rank} already claimed"))
}

/// Build the binomial relay wiring: one command/reply channel pair per
/// tree edge. The leader ends up holding only the root links; every
/// interior worker owns the links to its children and runs the relay
/// loop ([`spawn_tree_worker`]).
fn build_tree_wiring(
    shards: Vec<Shard>,
    obj: &Arc<dyn Objective>,
    gram_threads: Option<usize>,
    kills: &[Arc<AtomicBool>],
) -> TreeWiring {
    let m = shards.len();
    let plan = TreePlan::new(m);
    let mut cmd_tx: Vec<Option<RoundSender<Cmd>>> = Vec::with_capacity(m);
    let mut cmd_rx: Vec<Option<RoundReceiver<Cmd>>> = Vec::with_capacity(m);
    let mut rep_tx: Vec<Option<RoundSender<Reply>>> = Vec::with_capacity(m);
    let mut rep_rx: Vec<Option<RoundReceiver<Reply>>> = Vec::with_capacity(m);
    for _ in 0..m {
        let (ct, cr) = round_channel::<Cmd>();
        let (rt, rr) = round_channel::<Reply>();
        cmd_tx.push(Some(ct));
        cmd_rx.push(Some(cr));
        rep_tx.push(Some(rt));
        rep_rx.push(Some(rr));
    }
    // Hand each parent the downstream ends of its children's channels.
    let mut child_links: Vec<Vec<TreeChildLink>> = (0..m).map(|_| Vec::new()).collect();
    for r in 0..m {
        for &c in plan.children_of(r) {
            child_links[r].push(TreeChildLink {
                rank: c,
                ranks: plan.subtree_ranks(c),
                tx: claim(&mut cmd_tx[c], "child cmd end", c),
                rx: claim(&mut rep_rx[c], "child rep end", c),
            });
        }
    }
    let mut joins = Vec::with_capacity(m);
    let mut child_links = child_links.into_iter();
    for (id, shard) in shards.into_iter().enumerate() {
        // one link set per worker by construction (built in the loop above)
        let links = child_links.next().unwrap_or_default();
        joins.push(Some(spawn_tree_worker(
            id,
            shard,
            obj.clone(),
            gram_threads,
            kills[id].clone(),
            claim(&mut cmd_rx[id], "own cmd end", id),
            claim(&mut rep_tx[id], "own rep end", id),
            links,
        )));
    }
    let links = plan
        .root_links()
        .iter()
        .map(|ranks| {
            let root = ranks[0];
            TreeRootLink {
                ranks: ranks.clone(),
                tx: claim(&mut cmd_tx[root], "root cmd end", root),
                rx: claim(&mut rep_rx[root], "root rep end", root),
                dead: None,
            }
        })
        .collect();
    TreeWiring { links, joins }
}

/// The relay loop an interior (or leaf) tree worker runs: the in-memory
/// mirror of the TCP worker's serve session — commands fan out to
/// children before local compute, replies bundle upward in preorder,
/// and a dead child is answered for with synthesized `Reply::Err`
/// values so the frame-count discipline holds.
#[allow(clippy::too_many_arguments)]
fn spawn_tree_worker(
    id: usize,
    shard: Shard,
    obj: Arc<dyn Objective>,
    gram_threads: Option<usize>,
    kill: Arc<AtomicBool>,
    parent_rx: RoundReceiver<Cmd>,
    parent_tx: RoundSender<Reply>,
    children: Vec<TreeChildLink>,
) -> JoinHandle<()> {
    let builder = std::thread::Builder::new().name(format!("dane-tree-worker-{id}"));
    super::must_spawn(builder, move || {
            let mut worker = crate::worker::Worker::new(id, shard, obj);
            worker.set_gram_threads(gram_threads);
            let child_died = |rank: usize| {
                Reply::Err(format!("{RELAY_CHILD_LOST} {rank} died mid-round"))
            };
            while let Ok(cmd) = parent_rx.recv() {
                if kill.load(Ordering::Relaxed) {
                    return; // silent death: parent + children disconnect
                }
                match cmd {
                    Cmd::For { rank, inner } if rank != id => {
                        // Route toward the subtree that holds the target;
                        // exactly one reply flows back.
                        let reply = match children
                            .iter()
                            .find(|c| c.ranks.contains(&rank))
                        {
                            None => Reply::Err(format!(
                                "unroutable For: no subtree holds worker {rank}"
                            )),
                            Some(c) => {
                                if c.tx.send(Cmd::For { rank, inner }).is_ok() {
                                    c.rx.recv().unwrap_or_else(|_| child_died(c.rank))
                                } else {
                                    child_died(c.rank)
                                }
                            }
                        };
                        if parent_tx.send(reply).is_err() {
                            return;
                        }
                    }
                    cmd => {
                        // Broadcast round (For-to-self included: no child
                        // is addressed, execute_command unwraps it).
                        let fan_out = !matches!(cmd, Cmd::For { .. });
                        if fan_out {
                            for c in &children {
                                let _ = c.tx.send(cmd.relay_copy());
                            }
                        }
                        let own =
                            crate::worker::serve::execute_command(&mut worker, cmd);
                        if parent_tx.send(own).is_err() {
                            return;
                        }
                        if fan_out {
                            for c in &children {
                                for _ in 0..c.ranks.len() {
                                    let rep = c
                                        .rx
                                        .recv()
                                        .unwrap_or_else(|_| child_died(c.rank));
                                    if parent_tx.send(rep).is_err() {
                                        return;
                                    }
                                }
                            }
                        }
                    }
                }
            }
    })
}

impl ThreadedCluster {
    /// Disconnect and join every worker thread (star and tree wiring).
    /// Dropping the channel endpoints disconnects every worker: a
    /// worker blocked in recv gets Err and exits; one mid-compute fails
    /// its next reply send and exits. Shared by [`Drop`] and the
    /// full-rebuild path of [`Cluster::recover`].
    fn teardown_wiring(&mut self) {
        for h in self.handles.drain(..) {
            let WorkerHandle { tx, rx, join } = h;
            drop(tx);
            drop(rx);
            if let Some(j) = join {
                let _ = j.join();
            }
        }
        // Tree wiring: the leader only holds the root links; dropping
        // them unwinds the root children, whose dropped child links
        // unwind the next level — the disconnect cascades to the leaves,
        // after which every join completes.
        if let Some(mut tree) = self.tree.take() {
            tree.links.clear();
            for j in tree.joins.iter_mut() {
                if let Some(j) = j.take() {
                    let _ = j.join();
                }
            }
        }
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        self.teardown_wiring();
    }
}

impl Cluster for ThreadedCluster {
    fn m(&self) -> usize {
        self.weights.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.grad_and_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let loss = self.gather_grad_loss_into(w, g, true)?;
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(loss)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let loss = self.gather_loss(w)?;
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; self.d];
        self.dane_round_into(w_prev, g, eta, mu, &mut acc)?;
        Ok(acc)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        if self.compressor.is_some() {
            self.dane_round_compressed(w_prev, g, eta, mu, out)?;
            let m = self.m();
            self.comm.count_round(m, self.d);
            return Ok(());
        }
        if self.tree.is_some() {
            load_bcast(&mut self.bcast_w, w_prev);
            load_bcast(&mut self.bcast_g, g);
            let cmd = Cmd::DaneSolve {
                w_prev: self.bcast_w.clone(),
                g: self.bcast_g.clone(),
                eta,
                mu,
                out: Vec::new(),
            };
            out.fill(0.0);
            let inv_m = 1.0 / self.n_alive as f64;
            self.tree_round_fold(&cmd, &mut |i, r| match r {
                Reply::Vec(wi) if wi.len() == out.len() => {
                    // paper step (*): unweighted average in rank order
                    ops::axpy(inv_m, &wi, out);
                    Ok(())
                }
                _ => Err(crate::Error::Runtime(format!(
                    "worker {i}: unexpected reply type"
                ))),
            })?;
            let m = self.m();
            self.comm.count_round(m, self.d);
            return Ok(());
        }
        load_bcast(&mut self.bcast_w, w_prev);
        load_bcast(&mut self.bcast_g, g);
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            let buf = std::mem::take(&mut self.reply_pool[i]);
            let cmd = Cmd::DaneSolve {
                w_prev: self.bcast_w.clone(),
                g: self.bcast_g.clone(),
                eta,
                mu,
                out: buf,
            };
            match self.send_cmd(i, cmd) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        out.fill(0.0);
        // paper step (*) degrades to the unweighted average over the
        // surviving solvers
        let inv_m = 1.0 / self.n_alive as f64;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::Vec(wi)) => {
                    if first_err.is_none() {
                        // paper step (*): unweighted average in rank order
                        ops::axpy(inv_m, &wi, out);
                    }
                    self.reply_pool[i] = wi;
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(())
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        if self.tree.is_some() {
            // Worker 0 heads the first root link (TreePlan invariant),
            // so the For envelope reaches it without relaying.
            let cmd = Cmd::DaneSolve {
                w_prev: Arc::new(w_prev.to_vec()),
                g: Arc::new(g.to_vec()),
                eta,
                mu,
                out: Vec::new(),
            };
            let w1 = match self.tree_single(0, cmd)? {
                Reply::Vec(w) if w.len() == self.d => w,
                _ => return Err(self.unexpected(0)),
            };
            let m = self.m();
            self.comm.count_round(m, self.d);
            return Ok(w1);
        }
        // Only the first alive rank computes (rank 0 fault-free);
        // everyone else idles this round. Not a steady-state path, so
        // the reply vector is freshly allocated by the worker rather
        // than pooled.
        let first = (0..self.dead.len())
            .find(|&r| !self.dead[r])
            .ok_or_else(|| crate::Error::WorkerLost("no alive workers".into()))?;
        load_bcast(&mut self.bcast_w, w_prev);
        load_bcast(&mut self.bcast_g, g);
        self.send_cmd(
            first,
            Cmd::DaneSolve {
                w_prev: self.bcast_w.clone(),
                g: self.bcast_g.clone(),
                eta,
                mu,
                out: Vec::new(),
            },
        )?;
        let w1 = match self.recv_reply(first)? {
            Reply::Vec(w) => w,
            _ => return Err(self.unexpected(first)),
        };
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(w1)
    }

    fn prox_all(
        &mut self,
        targets: &[Vec<f64>],
        rho: f64,
    ) -> Result<Vec<Option<Vec<f64>>>> {
        assert_eq!(targets.len(), self.m());
        if self.tree.is_some() {
            // One ProxAll frame relays down the tree; each worker picks
            // its own target by rank (the uniform relay shape for the
            // only per-worker-payload collective).
            let cmd = Cmd::ProxAll { targets: targets.to_vec(), rho };
            let replies = self.tree_round(&cmd)?;
            let mut out = Vec::with_capacity(replies.len());
            for (i, r) in replies.into_iter().enumerate() {
                match r {
                    Reply::Vec(w) => out.push(Some(w)),
                    _ => return Err(self.unexpected(i)),
                }
            }
            return Ok(out);
        }
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for (i, v) in targets.iter().enumerate() {
            if self.dead[i] {
                continue;
            }
            match self.send_cmd(i, Cmd::Prox { v: v.clone(), rho }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        // slot by rank: dead ranks stay None
        let mut out: Vec<Option<Vec<f64>>> = (0..self.m()).map(|_| None).collect();
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::Vec(w)) => {
                    if first_err.is_none() {
                        out[i] = Some(w);
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Option<Vec<f64>>>, Option<Vec<Option<Vec<f64>>>>)> {
        if self.tree.is_some() {
            let replies = self.tree_round(&Cmd::Erm { subsample })?;
            let mut full = Vec::with_capacity(replies.len());
            let mut subs: Vec<Option<Vec<f64>>> = Vec::new();
            let mut any_sub = false;
            for (i, r) in replies.into_iter().enumerate() {
                match r {
                    Reply::VecPair(f, s) => {
                        full.push(Some(f));
                        if let Some(s) = s {
                            subs.push(Some(s));
                            any_sub = true;
                        }
                    }
                    _ => return Err(self.unexpected(i)),
                }
            }
            return Ok((full, if any_sub { Some(subs) } else { None }));
        }
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            match self.send_cmd(i, Cmd::Erm { subsample }) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut full: Vec<Option<Vec<f64>>> =
            (0..self.m()).map(|_| None).collect();
        let mut subs: Vec<Option<Vec<f64>>> = Vec::new();
        let mut any_sub = false;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::VecPair(f, s)) => {
                    if first_err.is_none() {
                        full[i] = Some(f);
                        if let Some(s) = s {
                            while subs.len() < i {
                                subs.push(None);
                            }
                            subs.push(Some(s));
                            any_sub = true;
                        }
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        if any_sub {
            while subs.len() < self.m() {
                subs.push(None);
            }
        }
        Ok((full, if any_sub { Some(subs) } else { None }))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Result<Vec<f64>> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        Ok(out)
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        if let Some(v) = self.row_sq {
            return Ok(v);
        }
        if self.tree.is_some() {
            let mut total = 0.0;
            let eff = std::mem::take(&mut self.eff_weights);
            let res = self.tree_round_fold(&Cmd::RowSq, &mut |i, r| match r {
                Reply::Scalar(v) => {
                    total += eff[i] * v;
                    Ok(())
                }
                _ => Err(crate::Error::Runtime(format!(
                    "worker {i}: unexpected reply type"
                ))),
            });
            self.eff_weights = eff;
            res?;
            let m = self.m();
            self.comm.count_round(m, 1);
            self.row_sq = Some(total);
            return Ok(total);
        }
        let mut sent = 0;
        let mut first_err: Option<crate::Error> = None;
        for i in 0..self.handles.len() {
            if self.dead[i] {
                continue;
            }
            match self.send_cmd(i, Cmd::RowSq) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut total = 0.0;
        let mut drained = 0;
        for i in 0..self.handles.len() {
            if drained == sent {
                break;
            }
            if self.dead[i] {
                continue;
            }
            drained += 1;
            match self.recv_reply(i) {
                Ok(Reply::Scalar(v)) => {
                    if first_err.is_none() {
                        total += self.eff_weights[i] * v;
                    }
                }
                Ok(other) => {
                    self.recycle(i, other);
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, 1);
        self.row_sq = Some(total);
        Ok(total)
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.gather_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.gather_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        let mut s = self.comm.stats().clone();
        s.alive_workers = self.n_alive as u64;
        s
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
    }

    fn alive(&self) -> usize {
        self.n_alive
    }

    fn restore_comm(&mut self, stats: &CommStats) {
        self.comm.restore(stats);
    }

    fn fault_kill_worker(&mut self, rank: usize) {
        self.kill_worker(rank);
    }

    fn enable_recovery(
        &mut self,
        ds: &Dataset,
        shard_seed: u64,
        gram_threads: Option<usize>,
    ) {
        // Re-sharding with the same seed reproduces the construction
        // shards exactly; workers are stateless between rounds, so a
        // respawn from the retained shard is indistinguishable from the
        // original thread.
        self.recovery = Some(RecoveryCtx {
            shards: shard_dataset(ds, self.weights.len(), shard_seed),
            gram_threads,
        });
    }

    /// Full-rebuild recovery: tear the whole round plane down, respawn
    /// every (non-quarantined) worker thread from the retained shards,
    /// and rewire as a **star** regardless of the original topology —
    /// star links work for every collective, and only faulted runs ever
    /// rebuild, so fault-free topology traces are untouched. Under
    /// `respawn` (`respawn == true`) everyone comes back; under
    /// `degrade` the kill switches flagged since the last rebuild are
    /// quarantined first and fold weights renormalize over survivors.
    fn recover(&mut self, respawn: bool) -> Result<usize> {
        let (shards, gram_threads) = match &self.recovery {
            Some(rec) => (rec.shards.clone(), rec.gram_threads),
            None => {
                return Err(crate::Error::Runtime(
                    "recovery not enabled on this threaded cluster".into(),
                ))
            }
        };
        let m = self.weights.len();
        if !respawn {
            for r in 0..m {
                if self.kills[r].load(Ordering::Relaxed) {
                    self.dead[r] = true;
                }
            }
        }
        self.teardown_wiring();
        self.kills =
            (0..m).map(|_| Arc::new(AtomicBool::new(false))).collect();
        self.handles = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| {
                // quarantined ranks get a thread too (uniform rank
                // indexing); it idles until Drop and never sees a
                // command
                spawn_worker(
                    id,
                    shard,
                    self.obj.clone(),
                    gram_threads,
                    self.kills[id].clone(),
                )
            })
            .collect();
        self.tree = None;
        self.reply_pool = vec![vec![0.0; self.d]; m];
        self.bcast_w = Arc::new(vec![0.0; self.d]);
        self.bcast_g = Arc::new(vec![0.0; self.d]);
        self.n_alive = self.dead.iter().filter(|&&dd| !dd).count();
        if self.dead.iter().any(|&dd| dd) {
            let wsum: f64 = (0..m)
                .filter(|&r| !self.dead[r])
                .map(|r| self.weights[r])
                .sum();
            self.eff_weights = (0..m)
                .map(|r| {
                    if self.dead[r] {
                        0.0
                    } else {
                        self.weights[r] / wsum
                    }
                })
                .collect();
            // weighted mean over a different worker set: recompute on
            // next use
            self.row_sq = None;
        } else {
            self.eff_weights = self.weights.clone();
        }
        Ok(self.n_alive)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{dane, RunCtx, SerialCluster};
    use crate::data::synthetic_fig2;
    use crate::loss::Ridge;
    use crate::solver::erm_solve;

    fn fixture() -> (Dataset, Arc<dyn Objective>, f64) {
        let lam = 0.01;
        let ds = synthetic_fig2(1024, 12, lam / 2.0, 7);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        (ds, obj, phi_star)
    }

    #[test]
    fn threaded_matches_serial_exactly() {
        let (ds, obj, _) = fixture();
        let mut serial = SerialCluster::new(&ds, obj.clone(), 4, 3);
        let mut threaded = ThreadedCluster::new(&ds, obj, 4, 3);
        let w = vec![0.1; 12];
        let (g1, l1) = serial.grad_and_loss(&w).unwrap();
        let (g2, l2) = threaded.grad_and_loss(&w).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);

        let d1 = serial.dane_round(&w, &g1, 1.0, 0.01).unwrap();
        let d2 = threaded.dane_round(&w, &g2, 1.0, 0.01).unwrap();
        for j in 0..12 {
            assert!((d1[j] - d2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn into_paths_match_allocating_paths_bitwise() {
        let (ds, obj, _) = fixture();
        let mut a = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let mut b = ThreadedCluster::new(&ds, obj, 4, 3);
        let w = vec![0.1; 12];
        let (g1, l1) = a.grad_and_loss(&w).unwrap();
        let mut g2 = vec![0.0; 12];
        let l2 = b.grad_and_loss_into(&w, &mut g2).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        let d1 = a.dane_round(&w, &g1, 1.0, 0.01).unwrap();
        let mut d2 = vec![0.0; 12];
        b.dane_round_into(&w, &g2, 1.0, 0.01, &mut d2).unwrap();
        assert_eq!(d1, d2);
    }

    #[test]
    fn broadcast_slots_are_reused_in_steady_state() {
        let (ds, obj, _) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        let mut w = vec![0.1; 12];
        let mut g = vec![0.0; 12];
        let mut w_next = vec![0.0; 12];
        cluster.grad_and_loss_into(&w, &mut g).unwrap();
        cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
        let wp = Arc::as_ptr(&cluster.bcast_w);
        let gp = Arc::as_ptr(&cluster.bcast_g);
        for _ in 0..5 {
            std::mem::swap(&mut w, &mut w_next);
            cluster.grad_and_loss_into(&w, &mut g).unwrap();
            cluster.dane_round_into(&w, &g, 1.0, 0.01, &mut w_next).unwrap();
            assert_eq!(Arc::as_ptr(&cluster.bcast_w), wp, "w slot reallocated");
            assert_eq!(Arc::as_ptr(&cluster.bcast_g), gp, "g slot reallocated");
            assert_eq!(Arc::strong_count(&cluster.bcast_w), 1);
        }
    }

    #[test]
    fn full_dane_run_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-9);
        let res = dane::run(&mut cluster, &Default::default(), &ctx).unwrap();
        assert!(res.converged, "{:?}", res.trace.suboptimality());
        // per completed iteration k: k+1 gradient rounds + k iterate rounds
        let last = res.trace.rows.last().unwrap();
        assert_eq!(last.comm_rounds, 2 * last.round as u64 + 1);
    }

    #[test]
    fn admm_and_osa_work_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-7);
        let res = crate::coordinator::admm::run(
            &mut cluster,
            &crate::coordinator::admm::AdmmOptions { rho: 0.1 },
            &ctx,
        )
        .unwrap();
        assert!(res.converged);

        let mut cluster = ThreadedCluster::new(&ds, obj, 8, 3);
        let ctx = RunCtx::new(1).with_reference(phi_star);
        let res = crate::coordinator::osa::run(
            &mut cluster,
            &crate::coordinator::osa::OsaOptions {
                bias_correction_r: Some(0.5),
                seed: 1,
            },
            &ctx,
        )
        .unwrap();
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 1);
    }

    #[test]
    fn worker_thread_shutdown_is_clean() {
        let (ds, obj, _) = fixture();
        let cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        drop(cluster); // must not hang or panic
    }

    fn tree_cluster(ds: &Dataset, obj: Arc<dyn Objective>, m: usize) -> ThreadedCluster {
        ThreadedCluster::with_topology(
            ds,
            obj,
            m,
            3,
            NetModel::free(),
            None,
            ExecTopology::Tree,
        )
    }

    #[test]
    fn tree_relay_matches_star_bitwise_on_every_collective() {
        let (ds, obj, _) = fixture();
        for m in [1usize, 2, 4, 7, 8] {
            let mut star = ThreadedCluster::new(&ds, obj.clone(), m, 3);
            let mut tree = tree_cluster(&ds, obj.clone(), m);
            assert_eq!(star.m(), m);
            assert_eq!(tree.m(), m);
            let w = vec![0.1; 12];
            let (gs, ls) = star.grad_and_loss(&w).unwrap();
            let (gt, lt) = tree.grad_and_loss(&w).unwrap();
            assert_eq!(gs, gt, "m={m}: gradient must be bit-identical");
            assert_eq!(ls, lt);
            assert_eq!(star.loss_only(&w).unwrap(), tree.loss_only(&w).unwrap());

            let ds1 = star.dane_round(&w, &gs, 1.0, 0.01).unwrap();
            let dt1 = tree.dane_round(&w, &gt, 1.0, 0.01).unwrap();
            assert_eq!(ds1, dt1, "m={m}: DANE average must be bit-identical");

            let fs = star.dane_round_first(&w, &gs, 1.0, 0.01).unwrap();
            let ft = tree.dane_round_first(&w, &gt, 1.0, 0.01).unwrap();
            assert_eq!(fs, ft, "m={m}: Theorem-5 path must be bit-identical");

            let targets: Vec<Vec<f64>> =
                (0..m).map(|k| vec![0.01 * k as f64; 12]).collect();
            assert_eq!(
                star.prox_all(&targets, 0.3).unwrap(),
                tree.prox_all(&targets, 0.3).unwrap(),
                "m={m}: prox"
            );
            let (es, _) = star.local_erms(Some((0.5, 3))).unwrap();
            let (et, _) = tree.local_erms(Some((0.5, 3))).unwrap();
            assert_eq!(es, et, "m={m}: local ERMs");
            assert_eq!(
                star.avg_row_sq_norm().unwrap(),
                tree.avg_row_sq_norm().unwrap()
            );
            // same round/byte accounting; modeled seconds differ only
            // through the NetModel topology, identical (free) here
            assert_eq!(star.comm_stats(), tree.comm_stats());
        }
    }

    #[test]
    fn full_dane_run_on_tree_matches_star() {
        let (ds, obj, phi_star) = fixture();
        let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-9);
        let mut star = ThreadedCluster::new(&ds, obj.clone(), 8, 3);
        let mut tree = tree_cluster(&ds, obj, 8);
        let rs = dane::run(&mut star, &Default::default(), &ctx).unwrap();
        let rt = dane::run(&mut tree, &Default::default(), &ctx).unwrap();
        assert!(rt.converged);
        assert_eq!(rs.w, rt.w, "final iterates must be bit-identical");
        assert_eq!(rs.trace.len(), rt.trace.len());
        for (a, b) in rs.trace.rows.iter().zip(&rt.trace.rows) {
            assert_eq!(a.objective, b.objective);
            assert_eq!(a.comm_rounds, b.comm_rounds);
            assert_eq!(a.comm_bytes, b.comm_bytes);
        }
    }

    #[test]
    fn killed_interior_tree_worker_surfaces_err_and_drains() {
        let (ds, obj, _) = fixture();
        // m=4: worker 0 relays for worker 2 — kill the *relay target*
        // (interior link) and the root child in turn
        for victim in [2usize, 0] {
            let mut tree = tree_cluster(&ds, obj.clone(), 4);
            let w = vec![0.1; 12];
            tree.grad_and_loss(&w).unwrap();
            tree.kill_worker(victim);
            let err = tree.grad_and_loss(&w).unwrap_err().to_string();
            assert!(err.contains("worker"), "victim {victim}: {err}");
            // every later round keeps failing instead of hanging
            assert!(tree.loss_only(&w).is_err(), "victim {victim}");
            assert!(tree.dane_round(&w, &w, 1.0, 0.01).is_err(), "victim {victim}");
        }
    }

    #[test]
    fn tree_cluster_shutdown_is_clean() {
        let (ds, obj, _) = fixture();
        let cluster = tree_cluster(&ds, obj, 8);
        drop(cluster); // must not hang or panic
    }

    #[test]
    fn respawn_recovery_matches_fresh_cluster_bitwise() {
        let (ds, obj, _) = fixture();
        let mut c = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        c.enable_recovery(&ds, 3, None);
        let w = vec![0.1; 12];
        let (g0, l0) = c.grad_and_loss(&w).unwrap();
        c.kill_worker(2);
        assert!(matches!(
            c.grad_and_loss(&w).unwrap_err(),
            crate::Error::WorkerLost(_)
        ));
        assert_eq!(c.recover(true).unwrap(), 4);
        assert_eq!(c.alive(), 4);
        let (g1, l1) = c.grad_and_loss(&w).unwrap();
        assert_eq!(g0, g1, "respawned cluster must reproduce the gradient");
        assert_eq!(l0, l1);
        assert_eq!(c.comm_stats().alive_workers, 4);
    }

    #[test]
    fn tree_recovery_rebuilds_as_star() {
        let (ds, obj, _) = fixture();
        let mut star = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let mut tree = tree_cluster(&ds, obj, 4);
        tree.enable_recovery(&ds, 3, None);
        let w = vec![0.1; 12];
        let expect = star.grad_and_loss(&w).unwrap();
        // kill the interior relay (rank 0 relays rank 2 at m=4)
        tree.kill_worker(0);
        assert!(tree.grad_and_loss(&w).is_err());
        assert_eq!(tree.recover(true).unwrap(), 4);
        let got = tree.grad_and_loss(&w).unwrap();
        assert_eq!(expect.0, got.0);
        assert_eq!(expect.1, got.1);
    }

    #[test]
    fn degrade_recovery_quarantines_and_renormalizes() {
        let (ds, obj, _) = fixture();
        let mut c = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        c.enable_recovery(&ds, 3, None);
        let w = vec![0.1; 12];
        c.kill_worker(1);
        assert!(c.grad_and_loss(&w).is_err());
        assert_eq!(c.recover(false).unwrap(), 3);
        assert_eq!(c.alive(), 3);
        assert_eq!(c.comm_stats().alive_workers, 3);

        // reference: a serial cluster over the surviving shards — its
        // n_i/N' weights are the renormalized fold up to rounding
        let shards = crate::data::shard_dataset(&ds, 4, 3);
        let survivors: Vec<_> = shards
            .into_iter()
            .enumerate()
            .filter(|(i, _)| *i != 1)
            .map(|(_, s)| s)
            .collect();
        let mut reference =
            SerialCluster::from_shards(survivors, obj, NetModel::free());
        let (g, l) = c.grad_and_loss(&w).unwrap();
        let (gr, lr) = reference.grad_and_loss(&w).unwrap();
        assert!((l - lr).abs() < 1e-12, "{l} vs {lr}");
        for j in 0..12 {
            assert!((g[j] - gr[j]).abs() < 1e-12);
        }

        // per-worker collectives mark the quarantined rank None
        let targets: Vec<Vec<f64>> = (0..4).map(|_| vec![0.1; 12]).collect();
        let prox = c.prox_all(&targets, 0.3).unwrap();
        assert!(prox[1].is_none());
        assert_eq!(prox.iter().filter(|p| p.is_some()).count(), 3);
        let (erms, _) = c.local_erms(None).unwrap();
        assert!(erms[1].is_none());

        // dane averages over survivors only
        let (gd, _) = c.eval_grad_loss(&w).unwrap();
        assert!(c.dane_round(&w, &gd, 1.0, 0.01).is_ok());
    }

    #[test]
    fn recover_without_arming_is_an_error() {
        let (ds, obj, _) = fixture();
        let mut c = ThreadedCluster::new(&ds, obj, 4, 3);
        let err = c.recover(true).unwrap_err().to_string();
        assert!(err.contains("recovery not enabled"), "{err}");
    }

    #[test]
    fn worker_error_does_not_desync_later_rounds() {
        use crate::linalg::{DataMatrix, DenseMatrix};
        // zero feature column -> singular Gram; lam = 0, mu = 0 makes the
        // cached-Cholesky local solve fail with a nonpositive pivot
        let mut rng = crate::util::Rng64::seed_from_u64(3);
        let mut x = DenseMatrix::zeros(32, 4);
        for i in 0..32 {
            for j in 0..3 {
                x.set(i, j, rng.range_f64(-1.0, 1.0));
            }
        }
        let y: Vec<f64> = (0..32).map(|i| (i % 3) as f64 - 1.0).collect();
        let ds = Dataset::new("degenerate", DataMatrix::Dense(x), y);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.0));
        let mut t = ThreadedCluster::new(&ds, obj.clone(), 4, 1);
        let w = vec![0.0; 4];
        let (g, _) = t.grad_and_loss(&w).unwrap();
        assert!(
            t.dane_round(&w, &g, 1.0, 0.0).is_err(),
            "singular local solve must surface an error"
        );
        // the failed round must have drained every reply: the survivor
        // and a fresh cluster agree bit-for-bit on the next rounds
        let mut fresh = ThreadedCluster::new(&ds, obj, 4, 1);
        fresh.grad_and_loss(&w).unwrap();
        let (g1, l1) = t.grad_and_loss(&w).unwrap();
        let (g2, l2) = fresh.grad_and_loss(&w).unwrap();
        assert_eq!(g1, g2);
        assert_eq!(l1, l2);
        assert_eq!(t.loss_only(&w).unwrap(), fresh.loss_only(&w).unwrap());
    }
}
