//! Threaded message-passing cluster engine.
//!
//! Where [`super::SerialCluster`] drives workers inline (deterministic,
//! the measurement engine for every figure), `ThreadedCluster` runs each
//! worker on its own OS thread behind an mpsc command/reply protocol —
//! the actual leader/worker process topology a deployment would have,
//! minus the sockets. Commands mirror the collective surface of the
//! [`super::Cluster`] trait; each round is a broadcast of one command and
//! a gather of m replies (a synchronous allreduce).
//!
//! (The design brief calls for tokio; the offline build has no tokio, so
//! this engine uses std::thread + channels — the same ownership and
//! message-flow structure, documented in DESIGN.md §5.)

use super::Cluster;
use crate::comm::{Collective, CommStats, NetModel};
use crate::data::{shard_dataset, Dataset, Shard};
use crate::linalg::ops;
use crate::loss::Objective;
use crate::Result;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Commands the leader broadcasts to workers.
enum Cmd {
    /// grad + loss at w -> Reply::VecScalar
    GradLoss(Arc<Vec<f64>>),
    /// loss at w -> Reply::Scalar
    Loss(Arc<Vec<f64>>),
    /// DANE local solve -> Reply::Vec
    DaneSolve { w_prev: Arc<Vec<f64>>, g: Arc<Vec<f64>>, eta: f64, mu: f64 },
    /// ADMM prox at a per-worker target -> Reply::Vec
    Prox { v: Vec<f64>, rho: f64 },
    /// local ERM (+ optional subsample) -> Reply::VecPair
    Erm { subsample: Option<(f64, u64)> },
    /// mean squared row norm -> Reply::Scalar
    RowSq,
    Shutdown,
}

enum Reply {
    Vec(Vec<f64>),
    Scalar(f64),
    VecScalar(Vec<f64>, f64),
    VecPair(Vec<f64>, Option<Vec<f64>>),
    Err(String),
}

struct WorkerHandle {
    tx: Sender<Cmd>,
    rx: Receiver<Reply>,
    join: Option<JoinHandle<()>>,
    /// n_i / N weight for exact gradient averaging.
    weight: f64,
}

/// Leader + m worker threads.
pub struct ThreadedCluster {
    handles: Vec<WorkerHandle>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
}

impl ThreadedCluster {
    pub fn new(ds: &Dataset, obj: Arc<dyn Objective>, m: usize, seed: u64) -> Self {
        Self::with_net(ds, obj, m, seed, NetModel::free())
    }

    pub fn with_net(
        ds: &Dataset,
        obj: Arc<dyn Objective>,
        m: usize,
        seed: u64,
        net: NetModel,
    ) -> Self {
        let shards = shard_dataset(ds, m, seed);
        let d = ds.d();
        let total: usize = shards.iter().map(|s| s.n_effective()).sum();
        let handles = shards
            .into_iter()
            .enumerate()
            .map(|(id, shard)| spawn_worker(id, shard, obj.clone(), total))
            .collect();
        ThreadedCluster { handles, obj, comm: Collective::new(net), d }
    }

    /// Broadcast one command to all workers, gather all replies in rank
    /// order. One synchronous phase — the thread-level allreduce body.
    fn round(&self, make: impl Fn(usize) -> Cmd) -> Result<Vec<Reply>> {
        for (i, h) in self.handles.iter().enumerate() {
            h.tx.send(make(i)).map_err(|_| {
                crate::Error::Runtime(format!("worker {i} channel closed"))
            })?;
        }
        let mut replies = Vec::with_capacity(self.handles.len());
        for (i, h) in self.handles.iter().enumerate() {
            match h.rx.recv() {
                Ok(Reply::Err(e)) => {
                    return Err(crate::Error::Runtime(format!("worker {i}: {e}")))
                }
                Ok(r) => replies.push(r),
                Err(_) => {
                    return Err(crate::Error::Runtime(format!(
                        "worker {i} died mid-round"
                    )))
                }
            }
        }
        Ok(replies)
    }

    fn weights(&self) -> Vec<f64> {
        self.handles.iter().map(|h| h.weight).collect()
    }

    fn gather_grad_loss(&self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let w = Arc::new(w.to_vec());
        let replies = self.round(|_| Cmd::GradLoss(w.clone()))?;
        let mut g = vec![0.0; self.d];
        let mut loss = 0.0;
        for (r, wt) in replies.into_iter().zip(self.weights()) {
            if let Reply::VecScalar(gi, li) = r {
                ops::axpy(wt, &gi, &mut g);
                loss += wt * li;
            }
        }
        Ok((g, loss))
    }
}

fn spawn_worker(
    id: usize,
    shard: Shard,
    obj: Arc<dyn Objective>,
    total_n: usize,
) -> WorkerHandle {
    let weight = shard.n_effective() as f64 / total_n as f64;
    let (cmd_tx, cmd_rx) = channel::<Cmd>();
    let (rep_tx, rep_rx) = channel::<Reply>();
    let join = std::thread::Builder::new()
        .name(format!("dane-worker-{id}"))
        .spawn(move || {
            let mut worker = crate::worker::Worker::new(id, shard, obj);
            let d = worker.dim();
            while let Ok(cmd) = cmd_rx.recv() {
                let reply = match cmd {
                    Cmd::GradLoss(w) => {
                        let mut g = vec![0.0; d];
                        match worker.grad(&w, &mut g) {
                            Ok(loss) => Reply::VecScalar(g, loss),
                            Err(e) => Reply::Err(e.to_string()),
                        }
                    }
                    Cmd::Loss(w) => Reply::Scalar(worker.loss(&w)),
                    Cmd::DaneSolve { w_prev, g, eta, mu } => {
                        match worker.dane_local_solve(&w_prev, &g, eta, mu) {
                            Ok(w) => Reply::Vec(w),
                            Err(e) => Reply::Err(e.to_string()),
                        }
                    }
                    Cmd::Prox { v, rho } => match worker.admm_prox(&v, rho) {
                        Ok(w) => Reply::Vec(w),
                        Err(e) => Reply::Err(e.to_string()),
                    },
                    Cmd::Erm { subsample } => {
                        let full = worker.local_erm();
                        match full {
                            Err(e) => Reply::Err(e.to_string()),
                            Ok(full) => match subsample {
                                None => Reply::VecPair(full, None),
                                Some((r, seed)) => {
                                    match worker.local_erm_subsample(r, seed) {
                                        Ok(sub) => Reply::VecPair(full, Some(sub)),
                                        Err(e) => Reply::Err(e.to_string()),
                                    }
                                }
                            },
                        }
                    }
                    Cmd::RowSq => {
                        let sh = worker.shard();
                        let mut total = 0.0;
                        for i in 0..sh.n_effective() {
                            total += super::row_sq_norm(sh, i);
                        }
                        Reply::Scalar(total / sh.n_effective() as f64)
                    }
                    Cmd::Shutdown => break,
                };
                if rep_tx.send(reply).is_err() {
                    break;
                }
            }
        })
        .expect("spawn worker thread");
    WorkerHandle { tx: cmd_tx, rx: rep_rx, join: Some(join), weight }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        for h in &self.handles {
            let _ = h.tx.send(Cmd::Shutdown);
        }
        for h in &mut self.handles {
            if let Some(j) = h.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Cluster for ThreadedCluster {
    fn m(&self) -> usize {
        self.handles.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let out = self.gather_grad_loss(w)?;
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(out)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let wv = Arc::new(w.to_vec());
        let replies = self.round(|_| Cmd::Loss(wv.clone()))?;
        let mut loss = 0.0;
        for (r, wt) in replies.into_iter().zip(self.weights()) {
            if let Reply::Scalar(l) = r {
                loss += wt * l;
            }
        }
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let wp = Arc::new(w_prev.to_vec());
        let gv = Arc::new(g.to_vec());
        let replies = self.round(|_| Cmd::DaneSolve {
            w_prev: wp.clone(),
            g: gv.clone(),
            eta,
            mu,
        })?;
        let mut acc = vec![0.0; self.d];
        let m = self.m() as f64;
        for r in replies {
            if let Reply::Vec(wi) = r {
                ops::axpy(1.0 / m, &wi, &mut acc);
            }
        }
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(acc)
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        // Only rank 0 computes; everyone else idles this round.
        let h = &self.handles[0];
        h.tx
            .send(Cmd::DaneSolve {
                w_prev: Arc::new(w_prev.to_vec()),
                g: Arc::new(g.to_vec()),
                eta,
                mu,
            })
            .map_err(|_| crate::Error::Runtime("worker 0 channel closed".into()))?;
        let w1 = match h.rx.recv() {
            Ok(Reply::Vec(w)) => w,
            Ok(Reply::Err(e)) => return Err(crate::Error::Runtime(e)),
            _ => return Err(crate::Error::Runtime("worker 0 bad reply".into())),
        };
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(w1)
    }

    fn prox_all(&mut self, targets: &[Vec<f64>], rho: f64) -> Result<Vec<Vec<f64>>> {
        assert_eq!(targets.len(), self.m());
        let replies = self.round(|i| Cmd::Prox { v: targets[i].clone(), rho })?;
        Ok(replies
            .into_iter()
            .map(|r| match r {
                Reply::Vec(w) => w,
                _ => unreachable!("prox reply type"),
            })
            .collect())
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Vec<f64>>, Option<Vec<Vec<f64>>>)> {
        let replies = self.round(|_| Cmd::Erm { subsample })?;
        let mut full = Vec::with_capacity(self.m());
        let mut subs: Vec<Vec<f64>> = Vec::new();
        let mut any_sub = false;
        for r in replies {
            if let Reply::VecPair(f, s) = r {
                full.push(f);
                if let Some(s) = s {
                    subs.push(s);
                    any_sub = true;
                }
            }
        }
        Ok((full, if any_sub { Some(subs) } else { None }))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        out
    }

    fn avg_row_sq_norm(&mut self) -> f64 {
        let replies = self.round(|_| Cmd::RowSq).expect("rowsq round");
        let mut total = 0.0;
        for (r, wt) in replies.into_iter().zip(self.weights()) {
            if let Reply::Scalar(v) = r {
                total += wt * v;
            }
        }
        let m = self.m();
        self.comm.count_round(m, 1);
        total
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        let wv = Arc::new(w.to_vec());
        let replies = self.round(|_| Cmd::Loss(wv.clone()))?;
        let mut loss = 0.0;
        for (r, wt) in replies.into_iter().zip(self.weights()) {
            if let Reply::Scalar(l) = r {
                loss += wt * l;
            }
        }
        Ok(loss)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        self.gather_grad_loss(w)
    }

    fn comm_stats(&self) -> CommStats {
        self.comm.stats().clone()
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{dane, RunCtx, SerialCluster};
    use crate::data::synthetic_fig2;
    use crate::loss::Ridge;
    use crate::solver::erm_solve;

    fn fixture() -> (Dataset, Arc<dyn Objective>, f64) {
        let lam = 0.01;
        let ds = synthetic_fig2(1024, 12, lam / 2.0, 7);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(lam));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        (ds, obj, phi_star)
    }

    #[test]
    fn threaded_matches_serial_exactly() {
        let (ds, obj, _) = fixture();
        let mut serial = SerialCluster::new(&ds, obj.clone(), 4, 3);
        let mut threaded = ThreadedCluster::new(&ds, obj, 4, 3);
        let w = vec![0.1; 12];
        let (g1, l1) = serial.grad_and_loss(&w).unwrap();
        let (g2, l2) = threaded.grad_and_loss(&w).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);

        let d1 = serial.dane_round(&w, &g1, 1.0, 0.01).unwrap();
        let d2 = threaded.dane_round(&w, &g2, 1.0, 0.01).unwrap();
        for j in 0..12 {
            assert!((d1[j] - d2[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn full_dane_run_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        let ctx = RunCtx::new(20).with_reference(phi_star).with_tol(1e-9);
        let res = dane::run(&mut cluster, &Default::default(), &ctx);
        assert!(res.converged, "{:?}", res.trace.suboptimality());
        // per completed iteration k: k+1 gradient rounds + k iterate rounds
        let last = res.trace.rows.last().unwrap();
        assert_eq!(last.comm_rounds, 2 * last.round as u64 + 1);
    }

    #[test]
    fn admm_and_osa_work_on_threads() {
        let (ds, obj, phi_star) = fixture();
        let mut cluster = ThreadedCluster::new(&ds, obj.clone(), 4, 3);
        let ctx = RunCtx::new(200).with_reference(phi_star).with_tol(1e-7);
        let res = crate::coordinator::admm::run(
            &mut cluster,
            &crate::coordinator::admm::AdmmOptions { rho: 0.1 },
            &ctx,
        );
        assert!(res.converged);

        let mut cluster = ThreadedCluster::new(&ds, obj, 8, 3);
        let ctx = RunCtx::new(1).with_reference(phi_star);
        let res = crate::coordinator::osa::run(
            &mut cluster,
            &crate::coordinator::osa::OsaOptions {
                bias_correction_r: Some(0.5),
                seed: 1,
            },
            &ctx,
        );
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 1);
    }

    #[test]
    fn worker_thread_shutdown_is_clean() {
        let (ds, obj, _) = fixture();
        let cluster = ThreadedCluster::new(&ds, obj, 4, 3);
        drop(cluster); // must not hang or panic
    }
}
