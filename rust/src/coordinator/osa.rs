//! One-shot parameter averaging (Zinkevich et al. 2010; Zhang et al. 2013)
//! — the single-round baseline of paper §2, with the optional subsample
//! bias correction whose failure mode Theorem 1 / Appendix A.2 dissects.
//!
//! Plain:      w_bar = mean_i argmin phi_i            (1 round total)
//! Corrected:  each machine solves the full-shard ERM w_i1 and a
//!             subsample-r ERM w_i2, returns (w_i1 - r w_i2)/(1 - r);
//!             the leader averages — still one round.

use super::{finish, AlgoOutcome, Cluster, RunCtx};
use crate::metrics::Trace;
use crate::Result;

/// OSA options.
#[derive(Debug, Clone, Copy, Default)]
pub struct OsaOptions {
    /// Subsample ratio r in (0,1) for the Zhang et al. bias correction;
    /// None = plain averaging.
    pub bias_correction_r: Option<f64>,
    /// Seed for the subsample draw.
    pub seed: u64,
}

/// Run one-shot averaging. The trace has exactly two rows: the zero
/// initial point and the averaged solution. Cluster failures return as
/// an error carrying the trace-so-far — never a panic.
pub fn run(cluster: &mut dyn Cluster, opts: &OsaOptions, ctx: &RunCtx) -> AlgoOutcome {
    let name = if opts.bias_correction_r.is_some() { "osa-bc" } else { "osa" };
    let mut w = vec![0.0; cluster.dim()];
    let mut trace = Trace::new();
    let mut converged = false;
    let res = run_inner(cluster, opts, ctx, &mut w, &mut trace, &mut converged);
    finish(name, res, w, trace, converged)
}

fn run_inner(
    cluster: &mut dyn Cluster,
    opts: &OsaOptions,
    ctx: &RunCtx,
    w: &mut Vec<f64>,
    trace: &mut Trace,
    converged: &mut bool,
) -> Result<()> {
    let obj = cluster.objective();
    let d = cluster.dim();
    let t0 = std::time::Instant::now();

    let loss0 = cluster.eval_loss(w)?;
    trace.push(
        0,
        loss0,
        ctx.subopt(loss0),
        None,
        ctx.test_loss(obj.as_ref(), w),
        &cluster.comm_stats(),
        0.0,
    );

    let sub = opts.bias_correction_r.map(|r| (r, opts.seed));
    let (full, subs) = cluster.local_erms(sub)?;

    // Per-machine combination (local), then ONE averaging round. Under a
    // degraded quorum absent ranks come back as None and drop out of the
    // mean (1/|alive|). OSA is single-shot, so there is no checkpoint —
    // a failed run is simply rerun.
    let combined: Vec<Vec<f64>> = match (&subs, opts.bias_correction_r) {
        (Some(subs), Some(r)) => full
            .iter()
            .zip(subs)
            .filter_map(|(w1, w2)| match (w1, w2) {
                (Some(w1), Some(w2)) => {
                    Some((0..d).map(|j| (w1[j] - r * w2[j]) / (1.0 - r)).collect())
                }
                _ => None,
            })
            .collect(),
        _ => full.into_iter().flatten().collect(),
    };
    *w = cluster.allreduce_mean_vecs(&combined)?;

    let loss = cluster.eval_loss(w)?;
    let subopt = ctx.subopt(loss);
    trace.push(
        1,
        loss,
        subopt,
        None,
        ctx.test_loss(obj.as_ref(), w),
        &cluster.comm_stats(),
        t0.elapsed().as_secs_f64(),
    );

    *converged = subopt.map(|s| s < ctx.tol).unwrap_or(false);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SerialCluster;
    use crate::data::synthetic_fig2;
    use crate::loss::{Objective, Ridge};
    use crate::solver::erm_solve;
    use std::sync::Arc;

    #[test]
    fn single_round_only() {
        let ds = synthetic_fig2(512, 8, 0.005, 5);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut cluster = SerialCluster::new(&ds, obj, 8, 3);
        let res = run(&mut cluster, &OsaOptions::default(), &RunCtx::new(1)).unwrap();
        assert_eq!(res.trace.rows.last().unwrap().comm_rounds, 1);
    }

    #[test]
    fn m1_osa_is_exact_erm() {
        let ds = synthetic_fig2(256, 6, 0.005, 6);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 1, 3);
        let ctx = RunCtx::new(1).with_reference(phi_star).with_tol(1e-9);
        let res = run(&mut cluster, &OsaOptions::default(), &ctx).unwrap();
        assert!(res.converged, "subopt {:?}", res.trace.last_suboptimality());
    }

    #[test]
    fn osa_improves_over_zero_but_not_exact() {
        let ds = synthetic_fig2(2048, 16, 0.005, 7);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let (_, phi_star) = erm_solve(obj.as_ref(), &ds.as_single_shard()).unwrap();
        let mut cluster = SerialCluster::new(&ds, obj, 16, 9);
        let ctx = RunCtx::new(1).with_reference(phi_star);
        let res = run(&mut cluster, &OsaOptions::default(), &ctx).unwrap();
        let s = res.trace.suboptimality();
        assert!(s[1] < s[0], "improves over w=0");
        assert!(s[1] > 1e-10, "but is not the exact ERM");
    }

    #[test]
    fn bias_correction_changes_result() {
        let ds = synthetic_fig2(1024, 8, 0.005, 8);
        let obj: Arc<dyn Objective> = Arc::new(Ridge::new(0.01));
        let mut c1 = SerialCluster::new(&ds, obj.clone(), 8, 3);
        let mut c2 = SerialCluster::new(&ds, obj, 8, 3);
        let plain = run(&mut c1, &OsaOptions::default(), &RunCtx::new(1)).unwrap();
        let bc = run(
            &mut c2,
            &OsaOptions { bias_correction_r: Some(0.5), seed: 1 },
            &RunCtx::new(1),
        )
        .unwrap();
        assert_eq!(bc.name, "osa-bc");
        let diff: f64 = plain
            .w
            .iter()
            .zip(&bc.w)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-10);
    }
}
