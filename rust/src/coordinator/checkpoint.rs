//! Checkpoint/resume: periodic binary snapshots of an algorithm run so
//! a crashed driver can continue **bit-exactly** where it left off.
//!
//! A checkpoint captures everything a round loop needs to reproduce its
//! next iteration: the named state vectors (iterate, momentum buffers,
//! ADMM duals, L-BFGS history), named scalars (step sizes), cumulative
//! [`CommStats`], and the trace-so-far. Floats are stored as raw IEEE
//! bit patterns (little-endian `f64::to_bits`), so a resumed run starts
//! from the *identical* f64s — no decimal round-trip — and the stitched
//! trace matches an uninterrupted run byte-for-byte (modulo the
//! wallclock column).
//!
//! Writes are atomic (`<path>.tmp` + rename): a crash mid-write leaves
//! the previous checkpoint intact, never a torn file.

use crate::comm::CommStats;
use crate::metrics::{Trace, TraceRow};
use crate::{Error, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 8] = b"DANECKPT";
// v2 added `payload_bytes_raw` to the CommStats and TraceRow records.
const VERSION: u32 = 2;

/// FNV-1a 64-bit hash of the canonical config JSON — stored in every
/// checkpoint and checked on `--resume` so a checkpoint can't silently
/// continue under a different experiment.
pub fn config_hash(canonical_json: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in canonical_json.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One resumable snapshot of an algorithm run.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Which algorithm wrote it ("dane", "gd", ...) — resume refuses a
    /// mismatch.
    pub algo: String,
    /// Last completed round: trace rows `0..=round` are recorded and
    /// the state vectors are post-update. Resume continues at
    /// `round + 1`.
    pub round: u64,
    /// Cumulative communication accounting at the snapshot.
    pub comm: CommStats,
    /// Named scalar state (step sizes, L-BFGS curvatures).
    pub scalars: Vec<(String, f64)>,
    /// Named vector state (iterate, duals, history pairs).
    pub vecs: Vec<(String, Vec<f64>)>,
    /// Trace rows recorded so far.
    pub trace: Trace,
    /// [`config_hash`] of the experiment config that produced the run.
    pub config_hash: u64,
}

impl Checkpoint {
    pub fn scalar(&self, name: &str) -> Option<f64> {
        self.scalars.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn vec(&self, name: &str) -> Option<&[f64]> {
        self.vecs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_slice())
    }

    /// Serialize and write atomically: the file at `path` is either the
    /// previous checkpoint or this one, never a torn mix.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let tmp = tmp_path(path);
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)?;
        Self::decode(&bytes).map_err(|msg| {
            Error::Runtime(format!(
                "checkpoint {}: {msg}",
                path.display()
            ))
        })
    }

    fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, VERSION);
        put_u64(&mut out, self.config_hash);
        put_str(&mut out, &self.algo);
        put_u64(&mut out, self.round);
        put_comm(&mut out, &self.comm);
        put_u32(&mut out, self.scalars.len() as u32);
        for (name, v) in &self.scalars {
            put_str(&mut out, name);
            put_f64(&mut out, *v);
        }
        put_u32(&mut out, self.vecs.len() as u32);
        for (name, v) in &self.vecs {
            put_str(&mut out, name);
            put_u32(&mut out, v.len() as u32);
            for x in v {
                put_f64(&mut out, *x);
            }
        }
        put_u32(&mut out, self.trace.rows.len() as u32);
        for r in &self.trace.rows {
            put_row(&mut out, r);
        }
        out
    }

    fn decode(bytes: &[u8]) -> std::result::Result<Checkpoint, String> {
        let mut rd = Reader { bytes, pos: 0 };
        if rd.take(8)? != MAGIC {
            return Err("bad magic (not a checkpoint file)".into());
        }
        let version = rd.u32()?;
        if version != VERSION {
            return Err(format!("unsupported version {version}"));
        }
        let config_hash = rd.u64()?;
        let algo = rd.string()?;
        let round = rd.u64()?;
        let comm = rd.comm()?;
        let n_scalars = rd.u32()? as usize;
        let mut scalars = Vec::with_capacity(n_scalars.min(1024));
        for _ in 0..n_scalars {
            let name = rd.string()?;
            scalars.push((name, rd.f64()?));
        }
        let n_vecs = rd.u32()? as usize;
        let mut vecs = Vec::with_capacity(n_vecs.min(1024));
        for _ in 0..n_vecs {
            let name = rd.string()?;
            let len = rd.u32()? as usize;
            let mut v = Vec::with_capacity(len.min(1 << 20));
            for _ in 0..len {
                v.push(rd.f64()?);
            }
            vecs.push((name, v));
        }
        let n_rows = rd.u32()? as usize;
        let mut trace = Trace::new();
        for _ in 0..n_rows {
            trace.rows.push(rd.row()?);
        }
        if rd.pos != bytes.len() {
            return Err("trailing bytes after checkpoint".into());
        }
        Ok(Checkpoint { algo, round, comm, scalars, vecs, trace, config_hash })
    }
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Driver-owned checkpoint policy for one run: where to write, how
/// often, and (on `--resume`) the restored snapshot. Shared into
/// [`super::RunCtx`] behind an `Arc` so the algorithm loops can call
/// [`CkptSpec::maybe_save`] without threading mutable state.
#[derive(Debug)]
pub struct CkptSpec {
    path: PathBuf,
    /// Save every `every` rounds (`round % every == 0`).
    every: usize,
    /// Snapshot restored from `--resume`, already validated by the
    /// driver (config hash + algorithm name).
    pub resume: Option<Checkpoint>,
    /// [`config_hash`] of the live config, stamped into every save.
    pub config_hash: u64,
    writes: AtomicU64,
    /// Chaos hook (`DANE_CHAOS_CRASH_AFTER=k`): hard-exit the process
    /// right after the k-th successful checkpoint write — the CI
    /// crash/resume scenario's deterministic "power cut".
    crash_after: Option<u64>,
}

impl CkptSpec {
    pub fn new(path: PathBuf, every: usize, config_hash: u64) -> Self {
        let crash_after = std::env::var("DANE_CHAOS_CRASH_AFTER")
            .ok()
            .and_then(|s| s.parse::<u64>().ok());
        CkptSpec {
            path,
            every: every.max(1),
            resume: None,
            config_hash,
            writes: AtomicU64::new(0),
            crash_after,
        }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The snapshot to restore for `algo`, if this spec carries one.
    pub fn resume_for(&self, algo: &str) -> Option<&Checkpoint> {
        self.resume.as_ref().filter(|c| c.algo == algo)
    }

    /// Round the loop should start from: one past the restored round,
    /// or 0 on a fresh run.
    pub fn start_round(&self, algo: &str) -> usize {
        self.resume_for(algo).map(|c| c.round as usize + 1).unwrap_or(0)
    }

    /// Save a snapshot if `round` is on the cadence. Called at the
    /// bottom of every algorithm iteration, after the state update and
    /// the trace push for `round`.
    pub fn maybe_save(
        &self,
        algo: &str,
        round: usize,
        comm: &CommStats,
        scalars: &[(&str, f64)],
        vecs: &[(&str, &[f64])],
        trace: &Trace,
    ) -> Result<()> {
        if round % self.every != 0 {
            return Ok(());
        }
        let ck = Checkpoint {
            algo: algo.to_string(),
            round: round as u64,
            comm: comm.clone(),
            scalars: scalars.iter().map(|(n, v)| (n.to_string(), *v)).collect(),
            vecs: vecs
                .iter()
                .map(|(n, v)| (n.to_string(), v.to_vec()))
                .collect(),
            trace: trace.clone(),
            config_hash: self.config_hash,
        };
        ck.save(&self.path)?;
        let done = self.writes.fetch_add(1, Ordering::SeqCst) + 1;
        if self.crash_after == Some(done) {
            eprintln!(
                "chaos: crashing after checkpoint write {done} (round {round})"
            );
            std::process::exit(3);
        }
        Ok(())
    }
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            put_f64(out, x);
        }
    }
}

fn put_comm(out: &mut Vec<u8>, c: &CommStats) {
    put_u64(out, c.rounds);
    put_u64(out, c.bytes);
    put_f64(out, c.modeled_seconds);
    put_u64(out, c.wire_bytes);
    put_u64(out, c.payload_bytes_raw);
    put_u64(out, c.startup_bytes);
    put_u64(out, c.alive_workers);
    put_u64(out, c.recoveries);
}

fn put_row(out: &mut Vec<u8>, r: &TraceRow) {
    put_u64(out, r.round as u64);
    put_f64(out, r.objective);
    put_opt_f64(out, r.suboptimality);
    put_opt_f64(out, r.grad_norm);
    put_opt_f64(out, r.test_loss);
    put_u64(out, r.comm_rounds);
    put_u64(out, r.comm_bytes);
    put_f64(out, r.comm_modeled_seconds);
    put_f64(out, r.elapsed_seconds);
    put_u64(out, r.wire_bytes);
    put_u64(out, r.payload_bytes_raw);
    put_u64(out, r.startup_bytes);
    put_u64(out, r.alive_workers);
    put_u64(out, r.recoveries);
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> std::result::Result<&'a [u8], String> {
        if self.pos + n > self.bytes.len() {
            return Err("truncated checkpoint".into());
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> std::result::Result<u32, String> {
        let b = self.take(4)?;
        let b: [u8; 4] = b.try_into().map_err(|_| "truncated checkpoint".to_string())?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> std::result::Result<u64, String> {
        let b = self.take(8)?;
        let b: [u8; 8] = b.try_into().map_err(|_| "truncated checkpoint".to_string())?;
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> std::result::Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn opt_f64(&mut self) -> std::result::Result<Option<f64>, String> {
        match self.take(1)?[0] {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            t => Err(format!("bad option tag {t}")),
        }
    }

    fn string(&mut self) -> std::result::Result<String, String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| "bad utf8".to_string())
    }

    fn comm(&mut self) -> std::result::Result<CommStats, String> {
        Ok(CommStats {
            rounds: self.u64()?,
            bytes: self.u64()?,
            modeled_seconds: self.f64()?,
            wire_bytes: self.u64()?,
            payload_bytes_raw: self.u64()?,
            startup_bytes: self.u64()?,
            alive_workers: self.u64()?,
            recoveries: self.u64()?,
        })
    }

    fn row(&mut self) -> std::result::Result<TraceRow, String> {
        Ok(TraceRow {
            round: self.u64()? as usize,
            objective: self.f64()?,
            suboptimality: self.opt_f64()?,
            grad_norm: self.opt_f64()?,
            test_loss: self.opt_f64()?,
            comm_rounds: self.u64()?,
            comm_bytes: self.u64()?,
            comm_modeled_seconds: self.f64()?,
            elapsed_seconds: self.f64()?,
            wire_bytes: self.u64()?,
            payload_bytes_raw: self.u64()?,
            startup_bytes: self.u64()?,
            alive_workers: self.u64()?,
            recoveries: self.u64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    // A value with a messy bit pattern, to prove bit-exact round-trips.
    const MESSY: f64 = std::f64::consts::PI / 3.0;

    fn sample() -> Checkpoint {
        let mut trace = Trace::new();
        let comm = CommStats {
            rounds: 7,
            bytes: 1024,
            modeled_seconds: 0.25,
            wire_bytes: 2048,
            payload_bytes_raw: 4096,
            startup_bytes: 512,
            alive_workers: 3,
            recoveries: 2,
        };
        trace.push(0, 1.5, Some(0.5), None, Some(0.9), &comm, 0.01);
        trace.push(1, 1.25, None, Some(1e-3), None, &comm, 0.02);
        Checkpoint {
            algo: "dane".into(),
            round: 1,
            comm,
            scalars: vec![("step".into(), 0.125)],
            vecs: vec![
                ("w".into(), vec![1.0, -2.5, MESSY]),
                ("g".into(), vec![]),
            ],
            trace,
            config_hash: config_hash("{\"name\":\"t\"}"),
        }
    }

    #[test]
    fn roundtrips_bit_exactly() {
        let ck = sample();
        let dir = TempDir::new("ckpt").unwrap();
        let path = dir.path().join("run.ckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ck);
        assert_eq!(back.vec("w").unwrap()[2].to_bits(), MESSY.to_bits());
        assert_eq!(back.scalar("step"), Some(0.125));
        assert!(back.scalar("missing").is_none());
        // no stray tmp file after the atomic rename
        assert!(!tmp_path(&path).exists());
    }

    #[test]
    fn save_overwrites_atomically() {
        let dir = TempDir::new("ckpt").unwrap();
        let path = dir.path().join("run.ckpt");
        let mut ck = sample();
        ck.save(&path).unwrap();
        ck.round = 5;
        ck.save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().round, 5);
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        let dir = TempDir::new("ckpt").unwrap();
        let path = dir.path().join("bad.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let ck = sample();
        let good = dir.path().join("good.ckpt");
        ck.save(&good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn spec_cadence_and_resume_gate() {
        let dir = TempDir::new("ckpt").unwrap();
        let path = dir.path().join("run.ckpt");
        let spec = CkptSpec::new(path.clone(), 3, 42);
        let trace = Trace::new();
        let comm = CommStats::default();
        let w = [1.0, 2.0];
        // rounds 1,2 skipped; 3 saved
        spec.maybe_save("gd", 1, &comm, &[], &[("w", &w)], &trace).unwrap();
        spec.maybe_save("gd", 2, &comm, &[], &[("w", &w)], &trace).unwrap();
        assert!(!path.exists());
        spec.maybe_save("gd", 3, &comm, &[], &[("w", &w)], &trace).unwrap();
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.round, 3);
        assert_eq!(ck.config_hash, 42);

        let mut spec = CkptSpec::new(path, 1, 42);
        assert_eq!(spec.start_round("gd"), 0);
        spec.resume = Some(ck);
        assert_eq!(spec.start_round("gd"), 4);
        // wrong algorithm: the snapshot is not offered
        assert_eq!(spec.start_round("dane"), 0);
        assert!(spec.resume_for("dane").is_none());
    }

    #[test]
    fn fnv_hash_is_stable_and_sensitive() {
        let a = config_hash("{\"seed\":1}");
        let b = config_hash("{\"seed\":2}");
        assert_ne!(a, b);
        assert_eq!(a, config_hash("{\"seed\":1}"));
        // FNV-1a of empty string is the offset basis
        assert_eq!(config_hash(""), 0xcbf2_9ce4_8422_2325);
    }
}
