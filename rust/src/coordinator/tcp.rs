//! TCP process-cluster engine: the round protocol over real sockets.
//!
//! Where [`super::SerialCluster`] drives workers inline and
//! [`super::threaded::ThreadedCluster`] runs them on OS threads,
//! `TcpCluster` runs each worker as a **separate OS process** speaking
//! the [`crate::comm::wire`] frame format over `std::net` sockets — the
//! paper's leader/worker topology with an actual wire in the middle.
//! Two deployment modes:
//!
//! * **external** ([`TcpCluster::connect`]) — the operator launches
//!   `dane worker --listen <addr>` anywhere reachable and lists the
//!   addresses in the config (`"workers": [...]`);
//! * **self-hosted** ([`TcpCluster::self_hosted`]) — the leader spawns
//!   its own worker child processes on loopback (`--listen 127.0.0.1:0`,
//!   parsing the announced port), so `engine: "tcp"` works with zero
//!   setup. The worker binary is the current executable, overridable via
//!   the `DANE_WORKER_BIN` env var (the test harness points it at the
//!   compiled `dane` bin).
//!
//! Workers receive their shard, objective and Gram-thread override in a
//! [`wire::Command::Init`] frame, so worker processes need no config
//! file and the leader remains the single source of sharding truth —
//! the same `shard_dataset(ds, m, seed)` call as the in-memory engines,
//! which is what makes a TCP run **trace-bit-identical** to a serial run
//! of the same config (`tests/tcp_cluster.rs` pins this through
//! `run_experiment`).
//!
//! Accounting: the modeled figures (`rounds`, `bytes`,
//! `modeled_seconds`) are counted exactly like the other engines, so
//! traces stay comparable; `CommStats::wire_bytes` additionally reports
//! the bytes *measured on the sockets* — every round-protocol frame
//! written or read, instrumentation rounds included; the one-time Init
//! (data distribution) is excluded, mirroring the modeled accounting,
//! which also only counts rounds.
//!
//! Hang safety: every stream carries read/write timeouts
//! ([`DEFAULT_IO_TIMEOUT`], override via [`TcpCluster::set_io_timeout`]),
//! so a wedged — not just dead — worker surfaces as an `Err` (and at the
//! CLI as an `AlgoError`) instead of deadlocking the leader. A failed
//! round drains every outstanding reply it can, like the threaded
//! engine, so surviving sockets never desynchronize. No
//! `.expect`/`.unwrap` anywhere on the socket path.

use super::Cluster;
use crate::comm::wire::{self, Command as Cmd, InitPayload, Reply};
use crate::comm::{Collective, CommStats, NetModel};
use crate::config::LossKind;
use crate::data::{shard_dataset, Dataset};
use crate::linalg::ops;
use crate::loss::{make_objective, Objective};
use crate::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Stdio};
use std::sync::Arc;
use std::time::Duration;

/// Default socket read/write timeout. Rounds are sub-second on every
/// in-tree workload; a worker silent this long is wedged, and an error
/// beats a deadlock.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(60);

struct WorkerLink {
    stream: TcpStream,
    /// Present in self-hosted mode; killed + reaped on drop.
    child: Option<Child>,
}

/// Leader + m worker processes over TCP.
pub struct TcpCluster {
    links: Vec<WorkerLink>,
    obj: Arc<dyn Objective>,
    comm: Collective,
    d: usize,
    /// n_i / N weights for exact gradient averaging (identical to the
    /// in-memory engines — same shards, same reduction order).
    weights: Vec<f64>,
    row_sq: Option<f64>,
    /// Bytes measured on the sockets (round frames only; Init excluded).
    wire_bytes: u64,
    /// Reusable encode buffer — one frame encoded per broadcast, written
    /// m times.
    enc: Vec<u8>,
    /// Reusable receive buffer.
    frame: Vec<u8>,
    io_timeout: Duration,
}

impl TcpCluster {
    /// Connect to externally-launched `dane worker --listen` processes.
    /// `m = addrs.len()`; shards are assigned to addresses in order.
    pub fn connect(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        addrs: &[String],
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
    ) -> Result<Self> {
        if addrs.is_empty() {
            return Err(Error::Config("tcp engine needs >= 1 worker address".into()));
        }
        let mut cluster = Self::empty(ds, loss, lambda, net, timeout);
        for (i, addr) in addrs.iter().enumerate() {
            let stream = TcpStream::connect(addr).map_err(|e| {
                Error::Runtime(format!("tcp: connect worker {i} at {addr}: {e}"))
            })?;
            cluster.add_link(stream, None)?;
        }
        cluster.init_workers(ds, loss, lambda, seed, gram_threads)?;
        Ok(cluster)
    }

    /// Spawn `m` worker child processes on loopback and connect to them.
    /// The worker binary is `$DANE_WORKER_BIN` if set, else the current
    /// executable (which is the `dane` bin when launched from the CLI).
    pub fn self_hosted(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        m: usize,
        seed: u64,
        net: NetModel,
        gram_threads: Option<usize>,
        timeout: Option<Duration>,
    ) -> Result<Self> {
        if m == 0 {
            return Err(Error::Config("tcp engine needs >= 1 worker".into()));
        }
        let bin = worker_binary()?;
        // `cluster` owns each child as soon as its link is pushed, so
        // any `?` below tears the already-started fleet down via Drop.
        let mut cluster = Self::empty(ds, loss, lambda, net, timeout);
        for i in 0..m {
            let (mut child, addr) = spawn_worker_process(&bin, i, cluster.io_timeout)?;
            let stream = match TcpStream::connect(&addr) {
                Ok(s) => s,
                Err(e) => {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(Error::Runtime(format!(
                        "tcp: connect spawned worker {i} at {addr}: {e}"
                    )));
                }
            };
            cluster.links.push(WorkerLink { stream, child: Some(child) });
            cluster.configure_stream(i)?;
        }
        cluster.init_workers(ds, loss, lambda, seed, gram_threads)?;
        Ok(cluster)
    }

    fn empty(
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        net: NetModel,
        timeout: Option<Duration>,
    ) -> Self {
        TcpCluster {
            links: Vec::new(),
            obj: make_objective(loss, lambda),
            comm: Collective::new(net),
            d: ds.d(),
            weights: Vec::new(),
            row_sq: None,
            wire_bytes: 0,
            enc: Vec::new(),
            frame: Vec::new(),
            io_timeout: timeout.unwrap_or(DEFAULT_IO_TIMEOUT),
        }
    }

    fn add_link(&mut self, stream: TcpStream, child: Option<Child>) -> Result<()> {
        self.links.push(WorkerLink { stream, child });
        self.configure_stream(self.links.len() - 1)
    }

    fn configure_stream(&mut self, i: usize) -> Result<()> {
        let s = &self.links[i].stream;
        s.set_nodelay(true)
            .map_err(|e| Error::Runtime(format!("tcp: worker {i} set_nodelay: {e}")))?;
        s.set_read_timeout(Some(self.io_timeout))
            .map_err(|e| Error::Runtime(format!("tcp: worker {i} read timeout: {e}")))?;
        s.set_write_timeout(Some(self.io_timeout))
            .map_err(|e| Error::Runtime(format!("tcp: worker {i} write timeout: {e}")))?;
        Ok(())
    }

    /// Re-arm the socket timeouts (tests tighten them to exercise the
    /// wedged-worker path quickly).
    pub fn set_io_timeout(&mut self, timeout: Duration) -> Result<()> {
        self.io_timeout = timeout;
        for i in 0..self.links.len() {
            self.configure_stream(i)?;
        }
        Ok(())
    }

    /// Shard the dataset (same seed discipline as the in-memory engines)
    /// and ship each worker its Init frame; lockstep ack gather.
    fn init_workers(
        &mut self,
        ds: &Dataset,
        loss: LossKind,
        lambda: f64,
        seed: u64,
        gram_threads: Option<usize>,
    ) -> Result<()> {
        let m = self.links.len();
        let shards = shard_dataset(ds, m, seed);
        if shards.len() != m {
            return Err(Error::Config(format!(
                "tcp: {} shards for {m} workers",
                shards.len()
            )));
        }
        let total: usize = shards.iter().map(|s| s.n_effective()).sum();
        self.weights = shards
            .iter()
            .map(|s| s.n_effective() as f64 / total as f64)
            .collect();
        for (i, shard) in shards.into_iter().enumerate() {
            let init = Cmd::Init(Box::new(InitPayload {
                worker_id: i,
                loss_name: loss.name().to_string(),
                lambda,
                gram_threads,
                shard,
            }));
            wire::encode_command(&init, &mut self.enc)?;
            self.write_frame_uncounted(i)?;
        }
        for i in 0..m {
            match self.recv_reply_uncounted(i)? {
                Reply::Scalar(_) => {}
                _ => {
                    return Err(Error::Runtime(format!(
                        "tcp: worker {i}: unexpected init ack"
                    )))
                }
            }
        }
        Ok(())
    }

    // ---- framed I/O --------------------------------------------------

    /// Write the frame sitting in `self.enc` to worker i, counting the
    /// bytes into `wire_bytes`.
    fn write_frame(&mut self, i: usize) -> Result<()> {
        self.write_frame_uncounted(i)?;
        self.wire_bytes += self.enc.len() as u64;
        Ok(())
    }

    fn write_frame_uncounted(&mut self, i: usize) -> Result<()> {
        self.links[i]
            .stream
            .write_all(&self.enc)
            .map_err(|e| io_err(i, "send", &e))
    }

    /// Read one reply frame from worker i, counting bytes; worker-side
    /// `Reply::Err` becomes an `Error::Runtime` like every round does.
    fn recv_reply(&mut self, i: usize) -> Result<Reply> {
        let n = self.read_reply_frame(i)?;
        self.wire_bytes += n as u64;
        self.decode_current_reply(i)
    }

    fn recv_reply_uncounted(&mut self, i: usize) -> Result<Reply> {
        self.read_reply_frame(i)?;
        self.decode_current_reply(i)
    }

    fn read_reply_frame(&mut self, i: usize) -> Result<usize> {
        match wire::read_frame(&mut self.links[i].stream, &mut self.frame) {
            Ok(Some(n)) => Ok(n),
            Ok(None) => Err(Error::Runtime(format!(
                "tcp: worker {i} closed the connection mid-round"
            ))),
            Err(Error::Io(e)) => Err(io_err(i, "reply read", &e)),
            Err(e) => Err(Error::Runtime(format!("tcp: worker {i}: {e}"))),
        }
    }

    fn decode_current_reply(&mut self, i: usize) -> Result<Reply> {
        match wire::decode_reply(&self.frame) {
            Ok(Reply::Err(e)) => {
                Err(Error::Runtime(format!("worker {i}: {e}")))
            }
            Ok(r) => Ok(r),
            Err(e) => Err(Error::Runtime(format!(
                "tcp: worker {i} sent a malformed reply: {e}"
            ))),
        }
    }

    fn unexpected(&self, i: usize) -> Error {
        Error::Runtime(format!("worker {i}: unexpected reply type"))
    }

    /// Broadcast the frame in `self.enc` to all workers; returns how
    /// many sends succeeded plus the first send error, mirroring the
    /// threaded engine's drain discipline.
    fn broadcast_enc(&mut self) -> (usize, Option<Error>) {
        let mut sent = 0;
        for i in 0..self.links.len() {
            match self.write_frame(i) {
                Ok(()) => sent += 1,
                Err(e) => return (sent, Some(e)),
            }
        }
        (sent, None)
    }

    // ---- gathers (shared by counted and instrumentation paths) -------

    fn gather_grad_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        wire::encode_command(
            &Cmd::GradLoss { w: Arc::new(w.to_vec()), out: Vec::new() },
            &mut self.enc,
        )?;
        let (sent, mut first_err) = self.broadcast_enc();
        g.fill(0.0);
        let mut loss = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::VecScalar(gi, li)) => {
                    if first_err.is_none() {
                        if gi.len() == g.len() {
                            ops::axpy(self.weights[i], &gi, g);
                            loss += self.weights[i] * li;
                        } else {
                            first_err = Some(self.unexpected(i));
                        }
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }

    fn gather_loss(&mut self, w: &[f64]) -> Result<f64> {
        wire::encode_command(&Cmd::Loss { w: Arc::new(w.to_vec()) }, &mut self.enc)?;
        let (sent, mut first_err) = self.broadcast_enc();
        let mut loss = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Scalar(l)) => {
                    if first_err.is_none() {
                        loss += self.weights[i] * l;
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }

    /// Kill worker child i (self-hosted mode) — the fault-injection
    /// tests' "machine dies mid-run". The socket is shut down too, so
    /// the very next round observes the death deterministically. A
    /// no-op on externally-launched workers.
    pub fn kill_worker(&mut self, i: usize) {
        if let Some(mut child) = self.links[i].child.take() {
            let _ = child.kill();
            let _ = child.wait();
        }
        let _ = self.links[i].stream.shutdown(std::net::Shutdown::Both);
    }
}

fn io_err(i: usize, what: &str, e: &std::io::Error) -> Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => Error::Runtime(format!(
            "tcp: worker {i} wedged: {what} timed out"
        )),
        _ => Error::Runtime(format!("tcp: worker {i} {what} failed: {e}")),
    }
}

fn worker_binary() -> Result<PathBuf> {
    if let Ok(p) = std::env::var("DANE_WORKER_BIN") {
        return Ok(PathBuf::from(p));
    }
    std::env::current_exe()
        .map_err(|e| Error::Runtime(format!("tcp: cannot locate worker binary: {e}")))
}

/// Parse the `listening on <addr>` line a worker announces on stdout.
fn parse_listen_line(line: &str) -> Option<&str> {
    let addr = line.trim().strip_prefix("listening on ")?;
    if addr.is_empty() {
        None
    } else {
        Some(addr)
    }
}

fn spawn_worker_process(
    bin: &PathBuf,
    i: usize,
    announce_timeout: Duration,
) -> Result<(Child, String)> {
    let mut child = std::process::Command::new(bin)
        .args(["worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .map_err(|e| {
            Error::Runtime(format!("tcp: spawn worker {i} ({}): {e}", bin.display()))
        })?;
    let Some(stdout) = child.stdout.take() else {
        let _ = child.kill();
        let _ = child.wait();
        return Err(Error::Runtime(format!("tcp: worker {i}: no stdout pipe")));
    };
    // Read the announce line on a helper thread so a child that never
    // prints (wrong binary, wedged startup) surfaces as an error within
    // the io timeout instead of hanging bring-up — the pipe read itself
    // has no timeout facility. Killing the child below unblocks the
    // helper (its read returns EOF), so it never lingers.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let mut line = String::new();
        let res = BufReader::new(stdout).read_line(&mut line).map(|_| line);
        let _ = tx.send(res);
    });
    let line = match rx.recv_timeout(announce_timeout) {
        Ok(Ok(line)) => line,
        Ok(Err(_)) | Err(_) => String::new(),
    };
    match parse_listen_line(&line).map(str::to_string) {
        Some(a) => Ok((child, a)),
        None => {
            let _ = child.kill();
            let _ = child.wait();
            Err(Error::Runtime(format!(
                "tcp: worker {i} did not announce its address within \
                 {announce_timeout:?} (got {line:?})"
            )))
        }
    }
}

impl Drop for TcpCluster {
    fn drop(&mut self) {
        // Closing the sockets lets externally-launched workers exit
        // their serve loop cleanly (EOF at a frame boundary); self-
        // hosted children are killed and reaped so no zombies outlive
        // the cluster.
        for link in self.links.drain(..) {
            let WorkerLink { stream, child } = link;
            drop(stream);
            if let Some(mut c) = child {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

impl Cluster for TcpCluster {
    fn m(&self) -> usize {
        self.links.len()
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn objective(&self) -> Arc<dyn Objective> {
        self.obj.clone()
    }

    fn grad_and_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.grad_and_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn grad_and_loss_into(&mut self, w: &[f64], g: &mut [f64]) -> Result<f64> {
        let loss = self.gather_grad_loss_into(w, g)?;
        let m = self.m();
        self.comm.count_round(m, self.d + 1);
        Ok(loss)
    }

    fn loss_only(&mut self, w: &[f64]) -> Result<f64> {
        let loss = self.gather_loss(w)?;
        let m = self.m();
        self.comm.count_round(m, 1);
        Ok(loss)
    }

    fn dane_round(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        let mut acc = vec![0.0; self.d];
        self.dane_round_into(w_prev, g, eta, mu, &mut acc)?;
        Ok(acc)
    }

    fn dane_round_into(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
        out: &mut [f64],
    ) -> Result<()> {
        wire::encode_command(
            &Cmd::DaneSolve {
                w_prev: Arc::new(w_prev.to_vec()),
                g: Arc::new(g.to_vec()),
                eta,
                mu,
                out: Vec::new(),
            },
            &mut self.enc,
        )?;
        let (sent, mut first_err) = self.broadcast_enc();
        out.fill(0.0);
        let inv_m = 1.0 / self.links.len() as f64;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Vec(wi)) => {
                    if first_err.is_none() {
                        if wi.len() == out.len() {
                            // paper step (*): unweighted average in rank order
                            ops::axpy(inv_m, &wi, out);
                        } else {
                            first_err = Some(self.unexpected(i));
                        }
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(())
    }

    fn dane_round_first(
        &mut self,
        w_prev: &[f64],
        g: &[f64],
        eta: f64,
        mu: f64,
    ) -> Result<Vec<f64>> {
        wire::encode_command(
            &Cmd::DaneSolve {
                w_prev: Arc::new(w_prev.to_vec()),
                g: Arc::new(g.to_vec()),
                eta,
                mu,
                out: Vec::new(),
            },
            &mut self.enc,
        )?;
        self.write_frame(0)?;
        let w1 = match self.recv_reply(0)? {
            Reply::Vec(w) if w.len() == self.d => w,
            _ => return Err(self.unexpected(0)),
        };
        let m = self.m();
        self.comm.count_round(m, self.d);
        Ok(w1)
    }

    fn prox_all(&mut self, targets: &[Vec<f64>], rho: f64) -> Result<Vec<Vec<f64>>> {
        assert_eq!(targets.len(), self.m());
        let mut sent = 0;
        let mut first_err: Option<Error> = None;
        for (i, v) in targets.iter().enumerate() {
            if let Err(e) = wire::encode_command(&Cmd::Prox { v: v.clone(), rho }, &mut self.enc)
            {
                first_err = Some(e);
                break;
            }
            match self.write_frame(i) {
                Ok(()) => sent += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        let mut out = Vec::with_capacity(self.m());
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Vec(w)) => {
                    if first_err.is_none() {
                        out.push(w);
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    fn local_erms(
        &mut self,
        subsample: Option<(f64, u64)>,
    ) -> Result<(Vec<Vec<f64>>, Option<Vec<Vec<f64>>>)> {
        wire::encode_command(&Cmd::Erm { subsample }, &mut self.enc)?;
        let (sent, mut first_err) = self.broadcast_enc();
        let mut full = Vec::with_capacity(self.m());
        let mut subs: Vec<Vec<f64>> = Vec::new();
        let mut any_sub = false;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::VecPair(f, s)) => {
                    if first_err.is_none() {
                        full.push(f);
                        if let Some(s) = s {
                            subs.push(s);
                            any_sub = true;
                        }
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok((full, if any_sub { Some(subs) } else { None }))
    }

    fn allreduce_mean_vecs(&mut self, vecs: &[Vec<f64>]) -> Vec<f64> {
        let mut out = vec![0.0; self.d];
        let views: Vec<&[f64]> = vecs.iter().map(|v| v.as_slice()).collect();
        self.comm.allreduce_mean(&views, &mut out);
        out
    }

    fn avg_row_sq_norm(&mut self) -> Result<f64> {
        if let Some(v) = self.row_sq {
            return Ok(v);
        }
        wire::encode_command(&Cmd::RowSq, &mut self.enc)?;
        let (sent, mut first_err) = self.broadcast_enc();
        let mut total = 0.0;
        for i in 0..sent {
            match self.recv_reply(i) {
                Ok(Reply::Scalar(v)) => {
                    if first_err.is_none() {
                        total += self.weights[i] * v;
                    }
                }
                Ok(_) => {
                    if first_err.is_none() {
                        first_err = Some(self.unexpected(i));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        let m = self.m();
        self.comm.count_round(m, 1);
        self.row_sq = Some(total);
        Ok(total)
    }

    fn eval_loss(&mut self, w: &[f64]) -> Result<f64> {
        self.gather_loss(w)
    }

    fn eval_grad_loss(&mut self, w: &[f64]) -> Result<(Vec<f64>, f64)> {
        let mut g = vec![0.0; self.d];
        let loss = self.gather_grad_loss_into(w, &mut g)?;
        Ok((g, loss))
    }

    fn comm_stats(&self) -> CommStats {
        let mut s = self.comm.stats().clone();
        s.wire_bytes = self.wire_bytes;
        s
    }

    fn reset_comm(&mut self) {
        self.comm.reset();
        self.wire_bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_line_parses() {
        assert_eq!(
            parse_listen_line("listening on 127.0.0.1:4471\n"),
            Some("127.0.0.1:4471")
        );
        assert_eq!(parse_listen_line("listening on "), None);
        assert_eq!(parse_listen_line("warming up"), None);
    }
}
